//! `dtsim` — CLI for the distributed-training scaling study.
//!
//! Subcommands:
//!   simulate   simulate one training configuration
//!   sweep      planner sweep over parallelization strategies
//!   study      run a registered scenario or an ad-hoc declarative grid
//!   repro      regenerate paper tables/figures (reports/*.csv)
//!   bench      perf smoke on the pinned grid -> BENCH_study.json
//!   collectives  collective cost model exploration
//!   train      real data-parallel training over AOT artifacts
//!   scenario   print metrics for a named config preset
//!   trace      export a chrome://tracing timeline for a config
//!   serve      long-running planner service (line-delimited JSON/TCP)
//!   client     send one request to a running `dtsim serve`
//!   store      verify or compact a result store file

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use dtsim::collectives::{collective_time, Collective};
use dtsim::config::scenario;
use dtsim::coordinator::{DistTrainer, TrainOptions};
use dtsim::hardware::{Catalog, HwId};
use dtsim::metrics;
use dtsim::planner::{self, SweepRequest};
use dtsim::report;
use dtsim::runtime::artifacts_root;
use dtsim::serve::{client::backoff_schedule, Client, Server};
use dtsim::sim::{build_engine, Jitter, Schedule, Sharding, SimConfig};
use dtsim::store::{LogStore, MemStore, ResultStore, StoreLock};
use dtsim::study::grid;
use dtsim::study::{
    grid_columns, ConsoleSink, CsvSink, JsonSink, ScenarioOpts, Sink,
    Study, StudyRunner,
};
use dtsim::topology::{Cluster, GroupPlacement};
use dtsim::trace::write_chrome_trace;
use dtsim::util::args::Args;
use dtsim::util::json::Json;
use dtsim::util::stats;

const USAGE: &str = "\
dtsim — Hardware Scaling Trends & Diminishing Returns reproduction

Every subcommand accepts --catalog hw.toml to load extra hardware
specs; loaded names work anywhere a --gen does (see docs/hardware.md).

USAGE:
  dtsim simulate   [--arch 7b|7b-moe8x|13b-moe16x] [--gen h100|<catalog>]
                   [--nodes 32 | --gpus 256] [--tp 1] [--pp 1] [--cp 1]
                   [--ep 1] [--gbs 512] [--mbs 2] [--seq 4096]
                   [--sharding fsdp|ddp|hsdp:G|zero3] [--ddp]
                   [--schedule 1f1b|interleaved:V]
                   [--sync sync|async:S]  # bounded-staleness DP
                   [--config run.toml]    # (docs/moe.md)
                   [--jitter lognormal:S|pareto:A [--seed N]
                    [--seeds K]]        # seeded per-op jitter
                                        # (docs/network.md)
                   [--ckpt off|auto|every:S] [--mtbf HOURS] [--elastic]
                                        # failure-aware goodput
                                        # (docs/reliability.md)
  dtsim sweep      [--arch 7b] [--gen h100] [--nodes 32] [--gbs 512]
                   [--seq 4096] [--cp] [--top 15] [--max-ep 8]
                   [--sharding fsdp] [--schedule 1f1b]
  dtsim study      <name> [--out reports] [--threads N] [--json]
                   [--catalog hw.toml] [--seed N]
                                        # e.g. madmax, straggler,
                                        # moe_crossover, async_straggler;
                                        # --seed reseeds stochastic
                                        # scenarios (replays exactly)
  dtsim study      --list
  dtsim study      --grid [--arch 7b,7b-moe8x] [--gen h100,<catalog>]
                   [--nodes 4,32 | --gpus 32,256]
                   [--plans sweep|sweep-cp|dp|tp2,tp4pp2]
                   [--ep 1,2,8]         # expert-parallel axis (MoE)
                   [--sync sync,async:4]
                   [--gbs 512,1024 | --lbs 2] [--mbs divisors|1,2,4]
                   [--seq 4096] [--sharding fsdp,ddp,hsdp:8,zero3]
                   [--schedule 1f1b,interleaved:2]
                   [--cap 0.94] [--top N] [--name my-grid]
                   [--jitter lognormal:0.15] [--seed 7] [--seeds 16]
                   [--ckpt off|auto|every:S] [--mtbf HOURS] [--elastic]
                   [--out DIR] [--json] [--threads N]
  dtsim repro      [fig1|fig2|...|fig14|table1|headline|all]
                   [--out reports]
  dtsim bench      [--out BENCH_study.json] [--threads N] [--quick]
                   [--compare BENCH_baseline.json] [--threshold 0.5]
  dtsim collectives [--gen h100] [--op allgather] [--mb 1024]
  dtsim train      [--config tiny] [--workers 2] [--steps 30]
                   [--lr 1e-3] [--threaded] [--ckpt path] [--seed 0]
  dtsim scenario   <weak-small|weak-large|strong-2n|strong-32n|
                    fig6-best|a100-32n|v100-32n>
  dtsim trace      --out trace.json [simulate flags]
  dtsim serve      [--addr 127.0.0.1:7071] [--store results.dtstore]
                   [--threads N] [--deadline-ms 0] [--max-conns 256]
                                    # line-delimited JSON over TCP;
                                    # --store persists results across
                                    # restarts and takes PATH.lock
                                    # (docs/serve.md)
  dtsim client     <ping|stats|simulate|plan|study-grid|scenario|
                    shutdown> [request flags]
                   [--addr 127.0.0.1:7071] [--retries 4]
                   [--backoff-ms 200] [--retry-seed N]
                                    # --retry-seed pins backoff jitter
                                    # (replays a chaos run exactly)
  dtsim store      <verify|compact> PATH
  dtsim store      migrate OLD NEW
                                    # verify: read-only scan, exit 4
                                    # on corruption; compact: drop
                                    # superseded/torn records,
                                    # answers stay bitwise-identical;
                                    # migrate: upgrade an old-schema
                                    # store (results kept bit-exact)
";

fn main() {
    let args = Args::from_env();
    // Load extra hardware specs before any --gen / study parsing, so
    // catalog names work everywhere built-ins do.
    if let Some(path) = args.get("catalog") {
        if let Err(e) = Catalog::load_file(path) {
            eprintln!("error: {e}");
            std::process::exit(1);
        }
    }
    // Arm deterministic fault points (DTSIM_FAULTS=spec, chaos
    // testing) before any subcommand runs; a typo'd spec must fail
    // loudly, never run clean while the operator believes faults are
    // armed.
    if let Err(e) = dtsim::fault::arm_from_env() {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
    let cmd = args.positional.first().cloned().unwrap_or_default();
    let result = match cmd.as_str() {
        "simulate" => cmd_simulate(&args),
        "sweep" => cmd_sweep(&args),
        "study" => cmd_study(&args),
        "repro" => cmd_repro(&args),
        "bench" => cmd_bench(&args),
        "collectives" => cmd_collectives(&args),
        "train" => cmd_train(&args),
        "scenario" => cmd_scenario(&args),
        "trace" => cmd_trace(&args),
        "serve" => cmd_serve(&args),
        "client" => cmd_client(&args),
        "store" => cmd_store(&args),
        _ => {
            eprint!("{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// `simulate`-style flags → `SimConfig` (shared with serve mode; see
/// `study::grid`).
fn sim_config_from(args: &Args) -> Result<SimConfig> {
    grid::sim_config_from_args(args).map_err(anyhow::Error::msg)
}

fn print_metrics(m: &metrics::Metrics) {
    println!("world size        : {} GPUs", m.world);
    println!("iteration time    : {:.1} ms", m.iter_time * 1e3);
    println!("global throughput : {:.0} words/s", m.global_wps);
    println!("per-GPU throughput: {:.0} words/s", m.per_gpu_wps);
    println!("achieved TFLOPS   : {:.1} /GPU", m.tflops_per_gpu);
    println!("MFU               : {:.2}%", m.mfu * 100.0);
    println!("compute time      : {:.1} ms", m.compute_time * 1e3);
    println!("comm kernel time  : {:.1} ms", m.comm_time * 1e3);
    println!("exposed comm      : {:.1} ms ({:.1}% of comm)",
             m.exposed_comm * 1e3, m.exposed_frac * 100.0);
    println!("power             : {:.0} W/GPU, {:.1} kW total",
             m.power_w, m.total_power_w / 1e3);
    println!("power efficiency  : {:.2} words/s/W", m.wps_per_watt);
    println!("energy            : {:.2} J/token", m.energy_per_token_j);
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let cfg = sim_config_from(args)?;
    println!("config: {} on {}x{} {} | plan {} | gbs {} mbs {} seq {}",
             cfg.arch.name, cfg.cluster.nodes,
             cfg.cluster.gpus_per_node(), cfg.cluster.node.gpu,
             cfg.plan, cfg.global_batch, cfg.micro_batch, cfg.seq_len);
    print_metrics(&metrics::evaluate(&cfg));
    // --seeds K replicates: iteration-time distribution over the
    // derived replicate seeds (replicate 0 is the base --seed, so the
    // headline metrics above are the first replicate verbatim).
    if cfg.jitter.replicates > 1 {
        let n = cfg.jitter.replicates as usize;
        let mut times = Vec::with_capacity(n);
        for r in 0..n {
            let mut c = cfg;
            c.jitter.seed = Jitter::replicate_seed(cfg.jitter.seed, r);
            c.jitter.replicates = 1;
            times.push(metrics::evaluate(&c).iter_time);
        }
        println!("iteration time over {} seeded replicates \
                  (jitter {}, seed {:#x}):",
                 n, cfg.jitter.dist, cfg.jitter.seed);
        for (label, p) in [("p50", 50.0), ("p95", 95.0), ("p99", 99.0)] {
            println!("  {label}             : {:.1} ms",
                     stats::percentile(&times, p) * 1e3);
        }
    }
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let arch = grid::parse_arch(&args.get_or("arch", "7b"))
        .map_err(anyhow::Error::msg)?;
    let gen = parse_hw(&args.get_or("gen", "h100"))?;
    let cluster = Cluster::new(gen, args.usize_or("nodes", 32));
    let req = SweepRequest {
        arch,
        cluster,
        global_batch: args.usize_or("gbs", 512),
        seq_len: args.usize_or("seq", 4096),
        with_cp: args.has("cp"),
        sharding: match args.get("sharding") {
            Some(s) => parse_sharding(s)?,
            None => Sharding::Fsdp,
        },
        schedule: match args.get("schedule") {
            Some(s) => parse_schedule(s)?,
            None => Schedule::OneFOneB,
        },
        max_ep: args.usize_or("max-ep", 1),
    };
    let top = args.usize_or("top", 15);
    println!("{:<18} {:>4} {:>12} {:>7} {:>11} {:>10} {:>8}",
             "plan", "mbs", "global_wps", "mfu", "exposed_ms",
             "wps_per_W", "mem_GB");
    for o in planner::sweep(&req).into_iter().take(top) {
        println!("{:<18} {:>4} {:>12.0} {:>6.1}% {:>11.1} {:>10.2} \
                  {:>8.1}",
                 o.plan.to_string(), o.micro_batch,
                 o.metrics.global_wps, o.metrics.mfu * 100.0,
                 o.metrics.exposed_comm * 1e3, o.metrics.wps_per_watt,
                 o.mem_per_gpu / 1e9);
    }
    Ok(())
}

/// `dtsim study` — registered scenarios and ad-hoc declarative grids.
fn cmd_study(args: &Args) -> Result<()> {
    let reg = report::registry();
    if args.has("list") {
        println!("registered scenarios:");
        for s in reg.iter() {
            println!("  {:<10} {}", s.name(), s.describe());
        }
        return Ok(());
    }

    let mut runner = match parse_threads(args)? {
        Some(n) => StudyRunner::new(n),
        None => StudyRunner::auto(),
    };
    let out = PathBuf::from(args.get_or("out", "reports"));

    if args.has("grid") {
        let study = study_from_args(args)?;
        let mut res = runner.run(&study);
        res.sort_by_wps();
        if let Some(top) = args.get("top") {
            res.truncate(top.parse().map_err(|_| anyhow!("bad --top"))?);
        }
        // Shared with serve mode's study-grid: unarmed grids keep the
        // historical columns byte-for-byte, seeded grids append the
        // iteration-time percentiles.
        let table =
            res.table(&grid_columns(!study.jitter().is_off(),
                                    study.has_async(),
                                    study.has_reliability()));
        ConsoleSink.emit(&table)?;
        CsvSink::new(&out).emit(&table)?;
        if args.has("json") {
            JsonSink::new(&out).emit(&table)?;
        }
        let (evaluated, requested) = runner.stats();
        println!(
            "\n{} grid points, {} simulated ({} deduplicated) on {} \
             threads; output in {}",
            requested, evaluated, requested - evaluated,
            runner.threads(), out.display());
        return Ok(());
    }

    let name = args
        .positional
        .get(1)
        .ok_or_else(|| anyhow!(
            "study name required (or --grid / --list)"))?;
    // Seeded scenarios (straggler) honor --seed; deterministic ones
    // ignore the options entirely.
    let mut sopts = ScenarioOpts::default();
    if let Some(s) = args.get("seed") {
        sopts.seed = Some(
            grid::parse_seed(s)
                .map_err(|e| anyhow!("--seed: {e}"))?,
        );
    }
    let tables = report::run_in_opts(&reg, &mut runner, name, &out,
                                     sopts)?;
    if args.has("json") {
        let mut json = JsonSink::new(&out);
        for t in &tables {
            json.emit(t)?;
        }
    }
    let (evaluated, requested) = runner.stats();
    println!(
        "\n{requested} grid points, {evaluated} simulated on {} \
         threads; output in {}",
        runner.threads(), out.display());
    Ok(())
}

/// Build a Study from `--grid` axis flags (shared with serve mode; see
/// `study::grid`).
fn study_from_args(args: &Args) -> Result<Study> {
    grid::study_from_args(args).map_err(anyhow::Error::msg)
}

/// Hardware-name parsing for `--gen`: built-ins plus anything loaded
/// via `--catalog`; the error enumerates every accepted form.
fn parse_hw(s: &str) -> Result<HwId> {
    grid::parse_hw(s).map_err(anyhow::Error::msg)
}

fn parse_sharding(s: &str) -> Result<Sharding> {
    grid::parse_sharding(s).map_err(anyhow::Error::msg)
}

fn parse_schedule(s: &str) -> Result<Schedule> {
    grid::parse_schedule(s).map_err(anyhow::Error::msg)
}

/// `--threads` parsing shared by `study`, `bench`, and `serve`:
/// `None` means one worker per core. Like `parse_hw`/`parse_sharding`,
/// the error enumerates the accepted forms instead of panicking.
fn parse_threads(args: &Args) -> Result<Option<usize>> {
    let Some(v) = args.get("threads") else {
        return Ok(None);
    };
    match v.parse::<usize>() {
        Ok(n) if n >= 1 => Ok(Some(n)),
        _ => bail!(
            "--threads: invalid worker count '{v}' (expected a \
             positive integer, e.g. --threads 4, or omit the flag for \
             one worker per core)"
        ),
    }
}

/// Millisecond-valued flag (`--deadline-ms`, `--backoff-ms`) parsing
/// in the `parse_threads` mold: absent means `default`, and the error
/// enumerates the accepted form. Zero is legal — it means "disabled"
/// where the flag documents that.
fn parse_ms_flag(args: &Args, key: &str, default: u64) -> Result<u64> {
    let Some(v) = args.get(key) else {
        return Ok(default);
    };
    v.parse::<u64>().map_err(|_| anyhow!(
        "--{key}: invalid duration '{v}' (expected whole \
         milliseconds, e.g. --{key} 1000, or omit the flag for the \
         default of {default})"
    ))
}

/// Count-valued flag (`--max-conns`, `--retries`) parsing, same mold.
fn parse_count_flag(args: &Args, key: &str, default: u64) -> Result<u64> {
    let Some(v) = args.get(key) else {
        return Ok(default);
    };
    v.parse::<u64>().map_err(|_| anyhow!(
        "--{key}: invalid count '{v}' (expected a non-negative \
         integer, e.g. --{key} 8, or omit the flag for the default \
         of {default})"
    ))
}

fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
}

fn cmd_repro(args: &Args) -> Result<()> {
    let out = PathBuf::from(args.get_or("out", "reports"));
    let which = args
        .positional
        .get(1)
        .cloned()
        .unwrap_or_else(|| "all".into());
    if which == "all" {
        report::run_all(&out)?;
    } else {
        report::run(&which, &out)?;
    }
    println!("\nCSV output in {}", out.display());
    Ok(())
}

/// `dtsim bench` — throughput smoke on the pinned benchmark grid
/// (`study::bench_pinned_study`, the Fig. 6 sweep at 256 GPUs), written
/// to a JSON file so CI tracks the perf trajectory across PRs:
/// configs/s on a cold runner, warm-cache rerun latency, the
/// collective cost-memo hit rate, steady-state compression counters,
/// and peak RSS. `--compare BASE.json` additionally prints per-field
/// deltas against a previous run and exits nonzero when a gated
/// throughput field regresses below `--threshold` (default 0.5) times
/// its baseline — the CI regression gate against the committed
/// `BENCH_baseline.json`.
fn cmd_bench(args: &Args) -> Result<()> {
    use std::time::Instant;

    let out = PathBuf::from(args.get_or("out", "BENCH_study.json"));
    let threads = parse_threads(args)?.unwrap_or_else(default_threads);
    let reps = if args.has("quick") { 2 } else { 5 };
    let study = dtsim::study::bench_pinned_study();
    let points = study.expand();

    // Cold full-grid throughput: fresh runner per rep, best rep wins
    // (min-noise convention, like the in-repo bench harness's p50).
    let mut best_cps = 0.0f64;
    let mut evaluated = 0usize;
    let mut cost_hits = 0u64;
    let mut cost_misses = 0u64;
    let mut steady = 0u64;
    let mut fallback = 0u64;
    let mut intervals = 0u64;
    let mut runs = 0u64;
    for _ in 0..reps {
        let mut runner = StudyRunner::new(threads);
        let t0 = Instant::now();
        runner.run(&study);
        let dt = t0.elapsed().as_secs_f64().max(1e-9);
        let (ev, _requested) = runner.stats();
        let cps = ev as f64 / dt;
        // Report a coherent snapshot: all stats come from the rep that
        // set the headline configs/s number.
        if cps > best_cps {
            best_cps = cps;
            evaluated = ev;
            (cost_hits, cost_misses) = runner.cost_cache_stats();
            (steady, fallback) = runner.steady_stats();
            (intervals, runs) = runner.interval_stats();
        }
    }
    // Steady-state compression diagnostics: what fraction of
    // evaluations took the wave driver, and how far run-coalescing
    // shrank the interval algebra.
    let steady_frac = if steady + fallback > 0 {
        steady as f64 / (steady + fallback) as f64
    } else {
        0.0
    };
    let interval_compression = if runs > 0 {
        intervals as f64 / runs as f64
    } else {
        0.0
    };

    // Warm rerun: every configuration served from the result store.
    // The store counters below come from this runner: the cold pass
    // records one miss per distinct config, the warm pass one hit.
    let mut warmed = StudyRunner::new(threads);
    warmed.run(&study);
    let t0 = Instant::now();
    warmed.run(&study);
    let warm_ms = t0.elapsed().as_secs_f64() * 1e3;
    let store_stats = warmed.store_stats();

    // Store recovery time: how long a `serve --store` restart spends
    // re-opening a log store holding this grid (informational — not a
    // gated field; it tracks the recovery scan, not the simulator).
    let recover_path = std::env::temp_dir().join(format!(
        "dtsim_bench_recover_{}.dtstore",
        std::process::id()
    ));
    let _ = std::fs::remove_file(&recover_path);
    let store_recover_ms = {
        {
            let (log, _) = LogStore::open(&recover_path)
                .map_err(|e| anyhow!("bench recovery store: {e}"))?;
            let mut runner =
                StudyRunner::with_store(threads, Arc::new(log));
            runner.run(&study);
        }
        let t0 = Instant::now();
        let _ = LogStore::open(&recover_path)
            .map_err(|e| anyhow!("bench recovery store: {e}"))?;
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        let _ = std::fs::remove_file(&recover_path);
        ms
    };

    // Schedule-variant companion grid (interleaved-1F1B + ZeRO-3 on
    // pipeline-heavy plans) so the new emitter arms are tracked in the
    // same artifact — included in --quick too.
    let sched_study = dtsim::study::bench_pinned_sched_study();
    let sched_points = sched_study.expand();
    let mut sched_runner = StudyRunner::new(threads);
    let t0 = Instant::now();
    sched_runner.run(&sched_study);
    let sched_dt = t0.elapsed().as_secs_f64().max(1e-9);
    let (sched_evaluated, _) = sched_runner.stats();
    let sched_cps = sched_evaluated as f64 / sched_dt;

    // Hardware-axis companion grid (every catalog built-in, incl. the
    // 72-GPU GB200 domain) so the interned-HwId cost-cache keying is
    // tracked in the same artifact — included in --quick too.
    let hw_study = dtsim::study::bench_pinned_hw_study();
    let hw_points = hw_study.expand();
    let mut hw_runner = StudyRunner::new(threads);
    let t0 = Instant::now();
    hw_runner.run(&hw_study);
    let hw_dt = t0.elapsed().as_secs_f64().max(1e-9);
    let (hw_evaluated, _) = hw_runner.stats();
    let hw_cps = hw_evaluated as f64 / hw_dt;
    let (hw_hits, hw_misses) = hw_runner.cost_cache_stats();
    let hw_hit_rate = if hw_hits + hw_misses > 0 {
        hw_hits as f64 / (hw_hits + hw_misses) as f64
    } else {
        0.0
    };

    // Stochastic companion grid (seeded lognormal jitter, 8 replicates
    // per point) so the jittered emitter path and percentile
    // aggregation are tracked in the same artifact. Informational —
    // not a gated field; replicate loops scale cost by --seeds, which
    // would gate a different quantity than the deterministic grids.
    let stoch_study = dtsim::study::bench_pinned_stochastic_study();
    let stoch_points = stoch_study.expand();
    let mut stoch_runner = StudyRunner::new(threads);
    let t0 = Instant::now();
    stoch_runner.run(&stoch_study);
    let stoch_dt = t0.elapsed().as_secs_f64().max(1e-9);
    let (stoch_evaluated, _) = stoch_runner.stats();
    let stoch_cps = stoch_evaluated as f64 / stoch_dt;

    // MoE / async companion grid (expert-parallel dispatch chain +
    // bounded-staleness sync axis) so the PR 9 emitter arms are
    // tracked in the same artifact. Informational — not a gated
    // field, same rationale as the stochastic grid: the axes change
    // per-point cost, so gating would compare different quantities.
    let moe_study = dtsim::study::bench_pinned_moe_study();
    let moe_points = moe_study.expand();
    let mut moe_runner = StudyRunner::new(threads);
    let t0 = Instant::now();
    moe_runner.run(&moe_study);
    let moe_dt = t0.elapsed().as_secs_f64().max(1e-9);
    let (moe_evaluated, _) = moe_runner.stats();
    let moe_cps = moe_evaluated as f64 / moe_dt;

    let queries = cost_hits + cost_misses;
    let hit_rate = if queries > 0 {
        cost_hits as f64 / queries as f64
    } else {
        0.0
    };
    let json = format!(
        "{{\n  \"bench\": \"study_runner/{}\",\n  \"grid_points\": {},\n  \
         \"simulated\": {},\n  \"configs_per_s\": {:.1},\n  \
         \"warm_rerun_ms\": {:.3},\n  \
         \"collective_cache_hit_rate\": {:.4},\n  \
         \"steady_driver_frac\": {:.4},\n  \
         \"interval_compression\": {:.2},\n  \
         \"sched_grid_points\": {},\n  \"sched_simulated\": {},\n  \
         \"sched_configs_per_s\": {:.1},\n  \
         \"hw_grid_points\": {},\n  \"hw_simulated\": {},\n  \
         \"hw_configs_per_s\": {:.1},\n  \
         \"hw_cache_hit_rate\": {:.4},\n  \
         \"stoch_grid_points\": {},\n  \"stoch_simulated\": {},\n  \
         \"stoch_configs_per_s\": {:.1},\n  \
         \"moe_grid_points\": {},\n  \"moe_simulated\": {},\n  \
         \"moe_configs_per_s\": {:.1},\n  \
         \"store_hits\": {},\n  \"store_misses\": {},\n  \
         \"store_bytes\": {},\n  \
         \"store_recover_ms\": {:.3},\n  \
         \"peak_rss_bytes\": {},\n  \"threads\": {},\n  \"reps\": {}\n}}\n",
        study.name, points.len(), evaluated, best_cps, warm_ms, hit_rate,
        steady_frac, interval_compression,
        sched_points.len(), sched_evaluated, sched_cps,
        hw_points.len(), hw_evaluated, hw_cps, hw_hit_rate,
        stoch_points.len(), stoch_evaluated, stoch_cps,
        moe_points.len(), moe_evaluated, moe_cps,
        store_stats.hits, store_stats.misses, store_stats.bytes,
        store_recover_ms, peak_rss_bytes(), threads, reps);
    if let Some(parent) = out.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(&out, &json)?;
    print!("{json}");
    println!("wrote {}", out.display());

    if let Some(base_path) = args.get("compare") {
        let threshold = args.f64_or("threshold", 0.5);
        compare_bench(&json, base_path, threshold)?;
    }
    Ok(())
}

/// Throughput-like fields gated by `dtsim bench --compare` (higher is
/// better): a run regresses when `current < threshold × baseline`.
const BENCH_GATED_FIELDS: &[&str] =
    &["configs_per_s", "sched_configs_per_s", "hw_configs_per_s"];

/// Compare a freshly-written bench JSON against a baseline file: print
/// per-field deltas for every numeric field the two runs share (in key
/// order), then fail (exit code 3) if any gated throughput field
/// dropped below `threshold` times its baseline. Non-gated fields (hit
/// rates, RSS, grid sizes) are informational only — they vary with the
/// grid and the host. Both documents go through the crate's JSON
/// parser (`util::json`), so free-text fields like the baseline's
/// `note` can never be misread as values.
fn compare_bench(current: &str, base_path: &str, threshold: f64)
    -> Result<()>
{
    if !(threshold > 0.0 && threshold <= 1.0) {
        bail!("--threshold {threshold} outside (0, 1]");
    }
    let base_text = std::fs::read_to_string(base_path)
        .map_err(|e| anyhow!("read baseline {base_path}: {e}"))?;
    let base = dtsim::util::json::Json::parse(&base_text)
        .map_err(|e| anyhow!("baseline {base_path}: {e}"))?;
    let current = dtsim::util::json::Json::parse(current)
        .map_err(|e| anyhow!("bench output: {e}"))?;
    println!("\ncomparing against {base_path} \
              (regression threshold {threshold}):");
    println!("{:<28} {:>14} {:>14} {:>9}",
             "field", "baseline", "current", "delta");
    for (key, bv) in base.as_object().into_iter().flatten() {
        let (Some(b), Some(c)) =
            (bv.as_f64(),
             current.get(key).and_then(|v| v.as_f64()))
        else {
            continue;
        };
        let delta = if b != 0.0 {
            format!("{:+.1}%", (c - b) / b * 100.0)
        } else {
            "n/a".to_string()
        };
        println!("{key:<28} {b:>14.3} {c:>14.3} {delta:>9}");
    }
    // A gated field the baseline cannot gate (absent, or a zeroed
    // value from a failed run) must be loud, not silently ungated.
    for key in BENCH_GATED_FIELDS {
        match base.get(key).and_then(|v| v.as_f64()) {
            Some(b) if b > 0.0 => {}
            _ => eprintln!(
                "warning: gate disabled for {key} — baseline value \
                 missing or non-positive; regenerate the baseline"),
        }
    }
    let regressions = bench_regressions(&current, &base, threshold);
    if !regressions.is_empty() {
        eprintln!("\nbench regression detected:");
        for r in &regressions {
            eprintln!("  {r}");
        }
        std::process::exit(3);
    }
    println!("\nno gated regressions.");
    Ok(())
}

/// Gated fields of `current` that fell below `threshold` times their
/// `base` value — the pure core of the `--compare` gate. Fields
/// missing from either document (older schemas) are skipped.
fn bench_regressions(
    current: &dtsim::util::json::Json,
    base: &dtsim::util::json::Json,
    threshold: f64,
) -> Vec<String> {
    let mut regressions = Vec::new();
    for key in BENCH_GATED_FIELDS {
        let (Some(b), Some(c)) =
            (base.get(key).and_then(|v| v.as_f64()),
             current.get(key).and_then(|v| v.as_f64()))
        else {
            continue;
        };
        if b > 0.0 && c < threshold * b {
            regressions.push(format!(
                "{key}: {c:.1} < {threshold} x baseline {b:.1}"));
        }
    }
    regressions
}

/// Peak resident set (VmHWM) in bytes; 0 where /proc is unavailable.
fn peak_rss_bytes() -> u64 {
    std::fs::read_to_string("/proc/self/status")
        .ok()
        .and_then(|s| {
            s.lines()
                .find(|l| l.starts_with("VmHWM:"))
                .and_then(|l| l.split_whitespace().nth(1))
                .and_then(|kb| kb.parse::<u64>().ok())
        })
        .map(|kb| kb * 1024)
        .unwrap_or(0)
}

fn cmd_collectives(args: &Args) -> Result<()> {
    let gen = parse_hw(&args.get_or("gen", "h100"))?;
    let op = match args.get_or("op", "allgather").as_str() {
        "allreduce" => Collective::AllReduce,
        "allgather" => Collective::AllGather,
        "reducescatter" => Collective::ReduceScatter,
        "broadcast" => Collective::Broadcast,
        "alltoall" => Collective::AllToAll,
        other => bail!("unknown --op {other}"),
    };
    let bytes = args.f64_or("mb", 1024.0) * 1e6;
    println!("{op} of {:.0} MB on {gen} DGX cluster:", bytes / 1e6);
    println!("{:>6} {:>7} {:>12} {:>12} {:>8}",
             "nodes", "gpus", "time_ms", "busbw_GB/s", "algo");
    for nodes in [1usize, 2, 4, 8, 16, 32, 64, 128, 256, 512] {
        let c = Cluster::new(gen, nodes);
        let place = GroupPlacement::strided(&c, c.world_size(), 1);
        let cost = collective_time(op, bytes, &c, &place);
        println!("{:>6} {:>7} {:>12.2} {:>12.1} {:>8?}",
                 nodes, c.world_size(), cost.time_s * 1e3,
                 cost.busbw / 1e9, cost.algo);
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let config = args.get_or("config", "tiny");
    let mut opts =
        TrainOptions::new(artifacts_root().join(&config));
    opts.workers = args.usize_or("workers", 2);
    opts.steps = args.usize_or("steps", 30);
    opts.lr = args.f64_or("lr", 1e-3) as f32;
    opts.warmup_steps = args.usize_or("warmup", opts.steps / 10 + 1);
    opts.seed = args.usize_or("seed", 0) as u64;
    opts.threaded = args.has("threaded");
    opts.log_every = args.usize_or("log-every", 10);
    if let Some(p) = args.get("ckpt") {
        opts.checkpoint_path = Some(PathBuf::from(p));
        opts.checkpoint_every = args.usize_or("ckpt-every", 0);
    }
    println!("training '{config}' with {} DP workers ({}) for {} steps",
             opts.workers,
             if opts.threaded { "threaded, one PJRT client each" }
             else { "sequential" },
             opts.steps);
    let mut trainer = DistTrainer::new(opts)?;
    let stats = trainer.train()?;
    println!("\nloss: {:.4} → {:.4} over {} steps",
             stats.first_loss(), stats.last_loss(), stats.final_step);
    println!("throughput: {:.0} tokens/s ({} tokens/step)",
             stats.wps(), stats.tokens_per_step);
    Ok(())
}

fn cmd_scenario(args: &Args) -> Result<()> {
    let name = args
        .positional
        .get(1)
        .ok_or_else(|| anyhow!("scenario name required"))?;
    let rc = scenario(name)
        .ok_or_else(|| anyhow!("unknown scenario '{name}'"))?;
    println!("scenario {name}: {} on {} {} nodes, plan {}",
             rc.arch.name, rc.nodes, rc.gen, rc.plan);
    print_metrics(&metrics::evaluate(&rc.sim()));
    Ok(())
}

fn cmd_trace(args: &Args) -> Result<()> {
    let cfg = sim_config_from(args)?;
    let out = args.get_or("out", "reports/trace.json");
    let eng = build_engine(&cfg);
    let tl = eng.run();
    write_chrome_trace(Path::new(&out), &eng, &tl)?;
    println!("wrote {} events to {out} (open in chrome://tracing)",
             eng.events.len());
    Ok(())
}

/// `dtsim serve` — the long-running planner service (docs/serve.md).
/// Without `--store` results live in memory for the process lifetime;
/// with `--store PATH` they ride the crash-recoverable on-disk log
/// (guarded by an advisory `PATH.lock` for the server's lifetime) and
/// survive restarts bit-identically.
fn cmd_serve(args: &Args) -> Result<()> {
    let threads = parse_threads(args)?.unwrap_or_else(default_threads);
    let addr = args.get_or("addr", "127.0.0.1:7071");
    let deadline_ms = parse_ms_flag(args, "deadline-ms", 0)?;
    let max_conns = parse_count_flag(args, "max-conns", 256)? as usize;
    // The lock must outlive the server: held in a local that drops
    // (removing PATH.lock) only after run() returns.
    let mut _lock: Option<StoreLock> = None;
    let store: Arc<dyn ResultStore> = match args.get("store") {
        Some(path) => {
            _lock = Some(
                StoreLock::acquire(path)
                    .map_err(|e| anyhow!("--store: {e}"))?,
            );
            let (store, recovery) =
                LogStore::open(path).map_err(|e| anyhow!(
                    "--store: {e} (expected a writable file path, \
                     e.g. --store results.dtstore — created on first \
                     use)"))?;
            println!(
                "store {path}: {} results recovered, {} stale \
                 skipped, {} trailing bytes truncated",
                recovery.recovered, recovery.skipped_stale,
                recovery.truncated_bytes);
            Arc::new(store)
        }
        None => Arc::new(MemStore::new()),
    };
    let persistent = args.has("store");
    let server = Server::bind(&addr, store, threads)
        .map_err(|e| anyhow!("--addr: {e}"))?
        .with_deadline_ms(deadline_ms)
        .with_max_conns(max_conns);
    println!(
        "dtsim serve listening on {} ({} threads per request, {} \
         store); send {{\"cmd\":\"shutdown\"}} or use `dtsim client \
         shutdown` to stop",
        server.local_addr()?, threads,
        if persistent { "persistent" } else { "in-memory" });
    server.run()?;
    println!("dtsim serve: shut down cleanly");
    Ok(())
}

/// `dtsim client <cmd> [flags]` — one request against a running
/// server. Every flag except `--addr`/`--catalog`/`--retries`/
/// `--backoff-ms` is forwarded as a request field, response lines
/// print verbatim (line-delimited JSON, pipe to `jq` at will), and an
/// `error` event exits nonzero.
///
/// Connect failures and mid-stream transport failures are retried up
/// to `--retries` times with exponential backoff plus jitter
/// (`--backoff-ms` base). Each retry re-issues the whole request on a
/// fresh connection — safe because completed points are committed to
/// the server's store before they are streamed, so a retried grid
/// resumes from the store and re-simulates only what is missing.
/// Server-side `error` events are final answers, never retried.
fn cmd_client(args: &Args) -> Result<()> {
    let cmd = args.positional.get(1).ok_or_else(|| anyhow!(
        "client command required (one of: ping, stats, simulate, \
         plan, study-grid, scenario, shutdown)"))?;
    let addr = args.get_or("addr", "127.0.0.1:7071");
    let retries = parse_count_flag(args, "retries", 4)? as u32;
    let backoff_ms = parse_ms_flag(args, "backoff-ms", 200)?.max(1);
    // Jitter is seeded per-invocation by default; `--retry-seed N`
    // pins it so a chaos run's whole retry timeline replays exactly.
    let retry_seed = match args.get("retry-seed") {
        Some(s) => s.parse::<u64>().map_err(|_| anyhow!(
            "--retry-seed: '{s}' is not a non-negative integer seed"))?,
        None => u64::from(std::process::id())
            ^ std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .map(|d| u64::from(d.subsec_nanos()))
                .unwrap_or(0),
    };
    let schedule = backoff_schedule(retries, backoff_ms, retry_seed);
    let mut req = BTreeMap::new();
    req.insert("cmd".to_string(), Json::Str(cmd.clone()));
    for (k, v) in args.flags() {
        if matches!(
            k,
            "addr" | "catalog" | "retries" | "backoff-ms" | "retry-seed"
        ) {
            continue;
        }
        req.insert(k.to_string(), Json::Str(v.to_string()));
    }
    let line = Json::Object(req).dump();

    let retry_hint = format!(
        "gave up after {} attempts — raise --retries N for more \
         attempts or --backoff-ms MS for a longer wait between them \
         (--retry-seed N replays this exact backoff timeline)",
        retries + 1);
    let mut last: Option<(&'static str, std::io::Error)> = None;
    for attempt in 0..=retries {
        if attempt > 0 {
            let (stage, e) =
                last.as_ref().expect("a retry follows a failure");
            let wait = schedule[(attempt - 1) as usize];
            eprintln!(
                "dtsim client: {stage} {addr} failed ({e}); retry \
                 {attempt}/{retries} in {wait}ms");
            std::thread::sleep(Duration::from_millis(wait));
        }
        let mut client = match Client::connect(&addr) {
            Ok(c) => c,
            Err(e) => {
                last = Some(("connect", e));
                continue;
            }
        };
        let lines = match client.request_raw(&line) {
            Ok(lines) => lines,
            Err(e) => {
                last = Some(("request to", e));
                continue;
            }
        };
        let mut failed = false;
        for line in &lines {
            println!("{line}");
            let event = Json::parse(line)
                .ok()
                .and_then(|v| {
                    v.get("event")
                        .and_then(|e| e.as_str())
                        .map(String::from)
                });
            if event.as_deref() == Some("error") {
                failed = true;
            }
        }
        if failed {
            std::process::exit(1);
        }
        return Ok(());
    }
    let (stage, e) = last.expect("exhausted retries imply a failure");
    if stage == "connect" {
        bail!(
            "connect {addr}: {e} (is `dtsim serve` running? pass \
             --addr to target a non-default address; {retry_hint})");
    }
    match e.kind() {
        std::io::ErrorKind::UnexpectedEof => bail!(
            "request to {addr}: {e} (the server or network dropped \
             the connection mid-response; points already streamed \
             were committed to the server's store, so re-running \
             this command resumes where it stopped; {retry_hint})"),
        std::io::ErrorKind::InvalidData => bail!(
            "request to {addr}: {e} (the response was corrupt — a \
             partial line or a non-JSON payload; is the address \
             really a `dtsim serve`? {retry_hint})"),
        _ => bail!("request to {addr}: {e} ({retry_hint})"),
    }
}

/// `dtsim store <verify|compact> PATH` — maintenance passes over a
/// result store file (docs/serve.md). `verify` is a read-only scan
/// that exits 4 on corruption; `compact` rewrites the file without
/// superseded duplicates or truncated garbage, and every stored
/// answer stays bitwise-identical; `migrate OLD NEW` upgrades an
/// old-schema store into a fresh current-schema file, result
/// payloads byte-verbatim.
fn cmd_store(args: &Args) -> Result<()> {
    const STORE_USAGE: &str =
        "store usage: `dtsim store verify PATH` (read-only scan; \
         exit 4 on corruption), `dtsim store compact PATH` (drop \
         superseded duplicates and truncated garbage; answers stay \
         bitwise-identical), or `dtsim store migrate OLD NEW` \
         (upgrade an old-schema store into a fresh file; results \
         kept bit-exact)";
    let verb = args
        .positional
        .get(1)
        .ok_or_else(|| anyhow!("store: missing action\n{STORE_USAGE}"))?;
    let path = args.positional.get(2).ok_or_else(|| {
        anyhow!("store {verb}: missing PATH\n{STORE_USAGE}")
    })?;
    match verb.as_str() {
        "verify" => {
            let report = dtsim::store::verify(path)
                .map_err(|e| anyhow!("store verify: {e}"))?;
            println!(
                "store {path}: {} results recovered, {} stale \
                 skipped, {} trailing bytes would be truncated",
                report.recovered, report.skipped_stale,
                report.truncated_bytes);
            if report.truncated_bytes > 0 {
                eprintln!(
                    "store verify: CORRUPT — {} trailing bytes fail \
                     the structural scan (a crash mid-append, or \
                     external damage); the committed records above \
                     are intact, and the next `dtsim serve --store \
                     {path}` or `dtsim store compact {path}` \
                     truncates the damage",
                    report.truncated_bytes);
                std::process::exit(4);
            }
            println!("store {path}: clean");
            Ok(())
        }
        "compact" => {
            // Same advisory lock as a server: compacting under a live
            // writer would silently drop its in-flight appends.
            let _lock = StoreLock::acquire(path)
                .map_err(|e| anyhow!("store compact: {e}"))?;
            let r = dtsim::store::compact(path)
                .map_err(|e| anyhow!("store compact: {e}"))?;
            println!(
                "store {path}: compacted {} -> {} bytes ({} live \
                 kept, {} superseded dropped, {} stale kept, {} \
                 bytes of truncated garbage dropped)",
                r.bytes_before, r.bytes_after, r.live,
                r.dropped_superseded, r.kept_stale, r.dropped_bytes);
            Ok(())
        }
        "migrate" => {
            let new = args.positional.get(3).ok_or_else(|| anyhow!(
                "store migrate: missing NEW output path\n{STORE_USAGE}"
            ))?;
            // Lock the *old* store: migrating out from under a live
            // writer would silently miss its in-flight appends.
            let _lock = StoreLock::acquire(path)
                .map_err(|e| anyhow!("store migrate: {e}"))?;
            let r = dtsim::store::migrate(path, new)
                .map_err(|e| anyhow!("store migrate: {e}"))?;
            println!(
                "store {path}: migrated {} ({} results re-encoded as \
                 {}, {} stale-hardware records dropped, {} bytes of \
                 truncated garbage left behind) -> {new}; the old \
                 file is untouched",
                r.from.name(), r.migrated,
                dtsim::store::codec::SchemaVersion::V4.name(),
                r.dropped_stale, r.truncated_bytes);
            Ok(())
        }
        other => {
            bail!("store: unknown action '{other}'\n{STORE_USAGE}")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use dtsim::study::grid::parse_plan_shape;

    const BENCH_JSON: &str = "{\n  \"bench\": \"study_runner/x\",\n  \
        \"note\": \"mentions configs_per_s freely\",\n  \
        \"grid_points\": 300,\n  \"configs_per_s\": 120.5,\n  \
        \"warm_rerun_ms\": 4.250,\n  \"sched_configs_per_s\": 80.0,\n  \
        \"hw_configs_per_s\": 44.0,\n  \"threads\": 2\n}\n";

    fn bench_json(text: &str) -> dtsim::util::json::Json {
        dtsim::util::json::Json::parse(text).expect("valid bench json")
    }

    #[test]
    fn bench_regression_gate_fires_only_below_threshold() {
        let base = bench_json(BENCH_JSON);
        // Current at exactly the baseline: no regression. The
        // free-text "note" field mentioning a gated key must not
        // confuse the (real JSON) parser.
        assert!(bench_regressions(&base, &base, 0.5).is_empty());
        // Halving the headline throughput at threshold 0.5 passes
        // (not strictly below); dropping further fails the gate.
        let half = bench_json(&BENCH_JSON.replace("120.5", "60.25"));
        assert!(bench_regressions(&half, &base, 0.5).is_empty());
        let tenth = bench_json(&BENCH_JSON.replace("120.5", "12.0"));
        let regs = bench_regressions(&tenth, &base, 0.5);
        assert_eq!(regs.len(), 1, "{regs:?}");
        assert!(regs[0].contains("configs_per_s"), "{regs:?}");
        // Non-gated fields never fire, even when they collapse.
        let slow_warm =
            bench_json(&BENCH_JSON.replace("4.250", "4000.0"));
        assert!(bench_regressions(&slow_warm, &base, 0.5).is_empty());
        // A baseline missing a gated field (older schema) is skipped.
        let old = bench_json(&BENCH_JSON.replace(
            "\"hw_configs_per_s\": 44.0,\n  ", ""));
        let cur = bench_json(&BENCH_JSON.replace("44.0", "1.0"));
        assert!(bench_regressions(&cur, &old, 0.5).is_empty());
        // The committed baseline parses and carries every gated field
        // with a positive (actually gating) value — a zeroed field
        // would silently disable its gate.
        let committed = std::fs::read_to_string("BENCH_baseline.json")
            .expect("committed baseline readable");
        let committed = bench_json(&committed);
        for key in BENCH_GATED_FIELDS {
            let v = committed.get(key).and_then(|v| v.as_f64());
            assert!(v.is_some_and(|v| v > 0.0),
                    "baseline gated field {key} missing or \
                     non-positive: {v:?}");
        }
        assert!(bench_regressions(&committed, &committed, 0.5)
            .is_empty());
    }

    #[test]
    fn plan_shapes_parse() {
        assert_eq!(parse_plan_shape("tp2"), Some((2, 1, 1)));
        assert_eq!(parse_plan_shape("tp2pp4"), Some((2, 4, 1)));
        assert_eq!(parse_plan_shape("tp2pp4cp2"), Some((2, 4, 2)));
        assert_eq!(parse_plan_shape("cp8"), Some((1, 1, 8)));
        assert_eq!(parse_plan_shape("dp8"), None);
        assert_eq!(parse_plan_shape("tp"), None);
        assert_eq!(parse_plan_shape(""), None);
        // Multi-byte input must be rejected, not panic on a byte split.
        assert_eq!(parse_plan_shape("tp2€pp2"), None);
    }

    #[test]
    fn shardings_parse() {
        assert_eq!(parse_sharding("fsdp").unwrap(), Sharding::Fsdp);
        assert_eq!(parse_sharding("ddp").unwrap(), Sharding::Ddp);
        assert_eq!(parse_sharding("hsdp:8").unwrap(),
                   Sharding::Hsdp { group: 8 });
        assert_eq!(parse_sharding("zero3").unwrap(), Sharding::Zero3);
        assert!(parse_sharding("hsdp:x").is_err());
        // The error names every accepted form (CLI discoverability).
        let err = parse_sharding("zero2").unwrap_err().to_string();
        assert!(err.contains("fsdp, ddp, hsdp:G, zero3"), "{err}");
    }

    #[test]
    fn schedules_parse() {
        assert_eq!(parse_schedule("1f1b").unwrap(), Schedule::OneFOneB);
        assert_eq!(parse_schedule("interleaved:2").unwrap(),
                   Schedule::Interleaved { v: 2 });
        assert!(parse_schedule("interleaved:1").is_err());
        assert!(parse_schedule("gpipe").is_err());
    }

    #[test]
    fn ddp_flag_conflicts_with_explicit_sharding() {
        let parse = |s: &str| {
            Args::parse(s.split_whitespace().map(String::from))
        };
        // Legacy shorthand alone still works.
        let cfg = sim_config_from(&parse("simulate --nodes 2 --ddp"))
            .unwrap();
        assert_eq!(cfg.sharding, Sharding::Ddp);
        // Explicit --sharding wins the namespace; a contradicting
        // --ddp is an error rather than a silent override.
        assert!(sim_config_from(
            &parse("simulate --nodes 2 --sharding zero3 --ddp"))
            .is_err());
        // ...but an agreeing pair is accepted.
        let cfg = sim_config_from(
            &parse("simulate --nodes 2 --sharding ddp --ddp")).unwrap();
        assert_eq!(cfg.sharding, Sharding::Ddp);
    }

    #[test]
    fn threads_errors_enumerate_accepted_forms() {
        let parse = |s: &str| {
            Args::parse(s.split_whitespace().map(String::from))
        };
        assert_eq!(parse_threads(&parse("study")).unwrap(), None);
        assert_eq!(parse_threads(&parse("study --threads 4")).unwrap(),
                   Some(4));
        let err = parse_threads(&parse("study --threads lots"))
            .unwrap_err()
            .to_string();
        assert!(err.contains("--threads"), "{err}");
        assert!(err.contains("'lots'"), "{err}");
        assert!(err.contains("positive integer"), "{err}");
        assert!(err.contains("--threads 4"), "{err}");
        // Zero workers and a bare valueless flag are both rejected
        // through the same enumerated message, not a panic.
        assert!(parse_threads(&parse("study --threads 0")).is_err());
        assert!(parse_threads(&parse("study --threads")).is_err());
    }

    #[test]
    fn gen_errors_enumerate_hardware_names() {
        let err = parse_hw("h900").unwrap_err().to_string();
        assert!(err.contains("--gen"), "{err}");
        assert!(err.contains("unknown hardware 'h900'"), "{err}");
        for name in ["v100", "a100", "h100", "gb200"] {
            assert!(err.contains(name), "{err} missing {name}");
        }
    }

    #[test]
    fn gpus_flag_sizes_the_cluster_or_reports_the_offender() {
        let parse = |s: &str| {
            Args::parse(s.split_whitespace().map(String::from))
        };
        let cfg = sim_config_from(
            &parse("simulate --gpus 64 --gbs 128")).unwrap();
        assert_eq!(cfg.cluster.nodes, 8);
        // Partial node: error names the offending count, no panic.
        let err = sim_config_from(&parse("simulate --gpus 100"))
            .unwrap_err()
            .to_string();
        assert!(err.contains("100"), "{err}");
        assert!(sim_config_from(
            &parse("simulate --gpus 64 --nodes 8")).is_err());

        // The study grid maps --gpus through the same boundary.
        let study = study_from_args(&parse(
            "study --grid --gpus 16,32 --plans dp --gbs 32 --mbs 1"))
            .unwrap();
        let nodes: Vec<usize> =
            study.expand().iter().map(|p| p.cfg.cluster.nodes).collect();
        assert_eq!(nodes, vec![2, 4]);
        let err = study_from_args(&parse(
            "study --grid --gpus 100 --plans dp"))
            .unwrap_err()
            .to_string();
        assert!(err.contains("100"), "{err}");
        assert!(study_from_args(&parse(
            "study --grid --gen h100,gb200 --gpus 144 --plans dp"))
            .is_err(), "mixed domain sizes cannot share --gpus");
    }

    #[test]
    fn grid_args_build_a_study() {
        let args = Args::parse(
            "study --grid --arch 7b --gen h100 --nodes 2 --gbs 48 \
             --plans sweep --mbs divisors"
                .split_whitespace()
                .map(String::from),
        );
        let study = study_from_args(&args).unwrap();
        let points = study.expand();
        assert!(!points.is_empty());
        assert!(points.iter().any(|p| p.cfg.micro_batch == 3),
                "divisor grid must include odd microbatches for gbs 48");
    }

    #[test]
    fn grid_args_cover_the_schedule_axis() {
        let args = Args::parse(
            "study --grid --arch 7b --nodes 2 --gbs 64 \
             --plans tp1pp4 --mbs divisors \
             --schedule 1f1b,interleaved:2 --sharding fsdp,zero3"
                .split_whitespace()
                .map(String::from),
        );
        let study = study_from_args(&args).unwrap();
        let points = study.expand();
        assert!(points.iter().any(
            |p| matches!(p.cfg.schedule, Schedule::Interleaved { v: 2 })));
        assert!(points.iter().any(
            |p| p.cfg.sharding == Sharding::Zero3));
        for p in &points {
            if let Schedule::Interleaved { .. } = p.cfg.schedule {
                assert_eq!(p.cfg.microbatches() % p.cfg.plan.pp, 0);
            }
        }
    }
}
