//! Named experiments and the registry that dispatches them.
//!
//! A [`Scenario`] is a self-contained experiment definition: it
//! declares one or more studies, runs them through a caller-provided
//! [`StudyRunner`] (so simulations are cached across scenarios), and
//! renders [`Table`]s. Every paper figure is a registered scenario
//! (`report::figures`), and downstream users register their own — see
//! `examples/study_api.rs`.

use anyhow::Result;

use super::runner::StudyRunner;
use super::table::Table;

/// Per-invocation options a scenario may honor. Every field is
/// optional; the plain [`Scenario::tables`] entry point passes the
/// defaults.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ScenarioOpts {
    /// Base-seed override for seeded (stochastic) scenarios — `--seed`
    /// on the CLI, a `"seed"` field in serve requests. Deterministic
    /// scenarios ignore it; seeded scenarios replay byte-identically
    /// for the same value.
    pub seed: Option<u64>,
}

/// A named, registrable experiment.
pub trait Scenario: Send + Sync {
    /// Registry key (`dtsim study <name>`).
    fn name(&self) -> &'static str;

    /// Table/figure title (rendered above the scenario's tables).
    fn title(&self) -> &'static str;

    /// One-line description for `dtsim study --list`. Defaults to the
    /// title; override to tell CLI users what the scenario *does*
    /// (axes swept, flags worth knowing) rather than what its figure
    /// is captioned.
    fn describe(&self) -> &'static str {
        self.title()
    }

    /// Execute and render. The runner is shared so repeated
    /// configurations across scenarios simulate once.
    fn tables(&self, runner: &mut StudyRunner) -> Result<Vec<Table>>;

    /// [`Scenario::tables`] with per-invocation [`ScenarioOpts`]. The
    /// default ignores the options, so deterministic scenarios
    /// implement only `tables`; seeded scenarios override this and
    /// route `tables` through it with the defaults.
    fn tables_with(
        &self,
        runner: &mut StudyRunner,
        opts: ScenarioOpts,
    ) -> Result<Vec<Table>> {
        let _ = opts;
        self.tables(runner)
    }
}

/// An ordered collection of scenarios, looked up by name.
#[derive(Default)]
pub struct Registry {
    items: Vec<Box<dyn Scenario>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry { items: Vec::new() }
    }

    /// Add a scenario. Panics on a duplicate name — registration is
    /// static wiring, and a silent shadow would be a footgun.
    pub fn register(&mut self, scenario: Box<dyn Scenario>) {
        assert!(
            self.get(scenario.name()).is_none(),
            "duplicate scenario '{}'",
            scenario.name()
        );
        self.items.push(scenario);
    }

    pub fn get(&self, name: &str) -> Option<&dyn Scenario> {
        self.items
            .iter()
            .find(|s| s.name() == name)
            .map(|b| b.as_ref())
    }

    pub fn names(&self) -> Vec<&'static str> {
        self.items.iter().map(|s| s.name()).collect()
    }

    pub fn iter(&self) -> impl Iterator<Item = &dyn Scenario> {
        self.items.iter().map(|b| b.as_ref())
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Dummy(&'static str);

    impl Scenario for Dummy {
        fn name(&self) -> &'static str {
            self.0
        }
        fn title(&self) -> &'static str {
            "dummy"
        }
        fn tables(&self, _runner: &mut StudyRunner) -> Result<Vec<Table>> {
            Ok(vec![Table::new(self.0, "dummy", &["a"])])
        }
    }

    #[test]
    fn register_and_lookup() {
        let mut reg = Registry::new();
        assert!(reg.is_empty());
        reg.register(Box::new(Dummy("one")));
        reg.register(Box::new(Dummy("two")));
        assert_eq!(reg.names(), vec!["one", "two"]);
        assert_eq!(reg.get("two").unwrap().title(), "dummy");
        // describe() defaults to the title unless overridden.
        assert_eq!(reg.get("two").unwrap().describe(), "dummy");
        assert!(reg.get("three").is_none());
        assert_eq!(reg.len(), 2);
    }

    #[test]
    #[should_panic(expected = "duplicate scenario")]
    fn duplicate_names_rejected() {
        let mut reg = Registry::new();
        reg.register(Box::new(Dummy("one")));
        reg.register(Box::new(Dummy("one")));
    }

    #[test]
    fn scenario_renders_through_runner() {
        let mut runner = StudyRunner::sequential();
        let tables = Dummy("d").tables(&mut runner).unwrap();
        assert_eq!(tables.len(), 1);
        assert_eq!(tables[0].name, "d");
    }
}
