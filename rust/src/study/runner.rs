//! Study execution: expands a grid, skips configurations already
//! simulated (keyed by [`ConfigKey`], resolved through a pluggable
//! [`ResultStore`]), and evaluates the remainder across scoped worker
//! threads.
//!
//! Determinism: results are assembled in grid-expansion order and every
//! sort downstream is stable, so a run with 1 thread and a run with N
//! threads produce byte-identical tables. The store makes figure
//! regeneration cheap too — the weak-scaling configs, for example, are
//! shared by Fig. 1, Fig. 3, and the headline table, and are simulated
//! exactly once per store (which may be shared across runners, across
//! serve-mode requests, and — with a persistent store — across process
//! restarts).
//!
//! Hot path: each worker owns a persistent [`SimArena`] (fused
//! simulation fast path, memoized collective costs, recycled buffers),
//! points are claimed through a chunked atomic-cursor work-stealing
//! loop, and results land in pre-sized lock-free `OnceLock` slots — no
//! per-point mutex. [`StudyRunner::best_of`] additionally runs a
//! parallel bound-and-prune search whose best-known achieved
//! throughput lives in a shared `AtomicU64`, so every worker's
//! analytic prune tightens the moment any worker improves the
//! incumbent — same winner as the exhaustive sweep, proven by tests.
//!
//! Serve mode drives the streamed/cancellable entry points
//! ([`StudyRunner::run_streamed`], [`StudyRunner::best_of_cancellable`]):
//! the same claim loops, with a per-request `AtomicBool` checked at
//! each claim so a disconnected client aborts the remaining work, and
//! an `emit` callback fired as each novel point completes.

use std::collections::hash_map::Entry;
use std::collections::{HashMap, HashSet};
use std::hash::Hash;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, OnceLock};

use crate::hardware::HwId;
use crate::memory;
use crate::metrics::{self, Metrics};
use crate::parallelism::ParallelPlan;
use crate::reliability;
use crate::sim::{self, Reliability, Schedule, Sharding, SimArena,
                 SimConfig, SyncMode};
use crate::store::{MemStore, ResultStore, StoreStats};
use crate::util::stats;

use super::table::{Column, Table};
use super::{ConfigKey, Study, StudyPoint};

/// One simulated grid point with its full metric set.
#[derive(Debug, Clone)]
pub struct CaseResult {
    pub arch: &'static str,
    /// Catalog hardware entry the case ran on.
    pub hw: HwId,
    pub nodes: usize,
    pub plan: ParallelPlan,
    pub global_batch: usize,
    pub micro_batch: usize,
    pub seq_len: usize,
    pub sharding: Sharding,
    pub schedule: Schedule,
    /// Gradient-sync discipline the case ran under (feeds the
    /// staleness-discounted effective-throughput column).
    pub sync: SyncMode,
    /// Failure/checkpoint axis the case was declared under (feeds the
    /// availability-discounted `goodput_wps` column; copied from the
    /// config key, never serialized in the result payload).
    pub relia: Reliability,
    /// Persistent per-GPU checkpoint footprint (param + optimizer
    /// shard bytes). A pure function of key-side data
    /// ([`memory::ckpt_bytes_per_gpu`]), so it is recomputed — not
    /// stored — wherever a `CaseResult` is rebuilt from its key.
    pub ckpt_bytes: f64,
    pub metrics: Metrics,
    /// Median iteration time over the point's seeded replicates. When
    /// jitter is off (or the point has a single replicate) every
    /// percentile equals `metrics.iter_time` bitwise — the distribution
    /// is a point mass at the deterministic run.
    pub iter_p50: f64,
    /// 95th-percentile iteration time over the seeded replicates.
    pub iter_p95: f64,
    /// 99th-percentile iteration time over the seeded replicates.
    pub iter_p99: f64,
    pub mem_per_gpu: f64,
}

impl CaseResult {
    /// Tokens processed per iteration (global batch × sequence length)
    /// — the numerator of every throughput objective.
    pub fn tokens_per_iter(&self) -> f64 {
        self.global_batch as f64 * self.seq_len as f64
    }

    /// Failure-aware goodput: raw throughput × the availability under
    /// the case's checkpoint cadence, hardware reliability figures,
    /// and world size (docs/reliability.md). Exactly `global_wps` when
    /// the reliability axis is off — the factor is 1.0 bit for bit.
    pub fn goodput_wps(&self) -> f64 {
        self.metrics.global_wps
            * reliability::goodput_factor(
                &self.relia,
                &self.hw.spec().reliability,
                self.metrics.world,
                self.plan.dp,
                self.ckpt_bytes,
            )
    }
}

/// Optimization target for [`StudyRunner::best_of_by`] and
/// [`StudyResult::best_by`]. Every objective is of the form
/// `factor × tokens / time` with `time ≥` the comm-free analytic lower
/// bound (jitter factors are clamped at 1, so a seeded replicate is
/// never faster than the deterministic run) and `factor ≤ 1` (the
/// availability discount), which keeps the bound-and-prune throughput
/// bound `tokens / lower_bound` sound for every variant.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Objective {
    /// Mean throughput: tokens / mean iteration time (the classic
    /// deterministic objective; [`StudyRunner::best_of`] uses this).
    MeanWps,
    /// Tail-aware throughput: tokens / p95 iteration time. With jitter
    /// off every percentile equals the deterministic iteration time,
    /// so this scores bitwise-identically to [`Objective::MeanWps`].
    P95Wps,
    /// Failure-aware goodput: `global_wps × availability` under the
    /// study's reliability axis ([`CaseResult::goodput_wps`]). The
    /// availability factor is in `[0, 1]`, so the raw-throughput prune
    /// bound stays an upper bound — a discounted candidate can only
    /// score lower, never higher, than its bound. With the axis off
    /// the factor is exactly 1.0 and this scores bitwise-identically
    /// to [`Objective::MeanWps`].
    GoodputWps,
}

impl Objective {
    /// The score `best_of_by`/`best_by` maximize for `case`.
    pub fn score(&self, case: &CaseResult) -> f64 {
        match self {
            Objective::MeanWps => case.metrics.global_wps,
            Objective::P95Wps => case.tokens_per_iter() / case.iter_p95,
            Objective::GoodputWps => case.goodput_wps(),
        }
    }
}

/// One worker's share of the bound-and-prune search: claim candidates
/// off the bound-sorted `todo` list through the atomic cursor, skip —
/// and stop, since bounds only shrink down the list — as soon as the
/// shared achieved-throughput bound dominates the claimed candidate,
/// otherwise simulate and publish the achieved throughput back into
/// the bound (`fetch_max` over f64 bits; sound because throughputs are
/// non-negative, where the IEEE total order matches the unsigned bit
/// order).
fn bound_search_loop(
    next: &AtomicUsize,
    todo: &[(usize, f64)],
    points: &[StudyPoint],
    slots: &[OnceLock<CaseResult>],
    bound: &AtomicU64,
    cancel: &AtomicBool,
    objective: Objective,
    arena: &mut SimArena,
) {
    loop {
        if cancel.load(Ordering::Relaxed) {
            break;
        }
        let i = next.fetch_add(1, Ordering::Relaxed);
        if i >= todo.len() {
            break;
        }
        let (idx, ub) = todo[i];
        let bw = f64::from_bits(bound.load(Ordering::Relaxed));
        if ub <= bw {
            // Bounds are sorted descending: this candidate and every
            // unclaimed one after it are dominated. Other workers
            // observe the same (or a tighter) bound on their next
            // claim and stop at most one step later.
            break;
        }
        let case = evaluate_point(&points[idx], arena);
        bound.fetch_max(objective.score(&case).to_bits(),
                        Ordering::Relaxed);
        let _ = slots[i].set(case);
    }
}

fn evaluate_point(p: &StudyPoint, arena: &mut SimArena) -> CaseResult {
    let (metrics, p50, p95, p99) = evaluate_replicated(&p.cfg, arena);
    CaseResult {
        arch: p.cfg.arch.name,
        hw: p.cfg.cluster.node.gpu,
        nodes: p.cfg.cluster.nodes,
        plan: p.cfg.plan,
        global_batch: p.cfg.global_batch,
        micro_batch: p.cfg.micro_batch,
        seq_len: p.cfg.seq_len,
        sharding: p.cfg.sharding,
        schedule: p.cfg.schedule,
        sync: p.cfg.sync,
        relia: p.cfg.relia,
        ckpt_bytes: memory::ckpt_bytes_per_gpu(
            &p.cfg.arch, &p.cfg.plan, p.cfg.sharding),
        metrics,
        iter_p50: p50,
        iter_p95: p95,
        iter_p99: p99,
        mem_per_gpu: p.mem_per_gpu,
    }
}

/// Evaluate a config's seeded replicate distribution. Replicate `r`
/// re-runs the simulation with seed [`sim::Jitter::replicate_seed`]
/// `(base, r)`; the percentiles summarize the iteration-time sample
/// and the headline metrics derive from the replicate-mean report
/// (per-stage detail and tag totals are distribution-level noise and
/// stay empty in the aggregate — the metric derivation never reads
/// them). The single-replicate path — which includes every unarmed
/// config — takes the exact historical route, so jitter=off results
/// are bit-identical to the pre-stochastic runner.
fn evaluate_replicated(
    cfg: &SimConfig,
    arena: &mut SimArena,
) -> (Metrics, f64, f64, f64) {
    let n = cfg.jitter.replicates as usize;
    if n == 1 {
        let m = metrics::evaluate_in(cfg, arena);
        let t = m.iter_time;
        return (m, t, t, t);
    }
    let mut times = Vec::with_capacity(n);
    let mut agg = sim::IterationReport {
        iter_time: 0.0,
        stages: Vec::new(),
        compute_busy: 0.0,
        comm_busy: 0.0,
        comm_kernel_time: 0.0,
        exposed_comm: 0.0,
        idle: 0.0,
        comm_by_tag: sim::TagTotals::new(),
    };
    for r in 0..n {
        let mut c = *cfg;
        c.jitter.seed = sim::Jitter::replicate_seed(cfg.jitter.seed, r);
        c.jitter.replicates = 1;
        let rep = sim::simulate_in(&c, arena);
        times.push(rep.iter_time);
        agg.iter_time += rep.iter_time;
        agg.compute_busy += rep.compute_busy;
        agg.comm_busy += rep.comm_busy;
        agg.comm_kernel_time += rep.comm_kernel_time;
        agg.exposed_comm += rep.exposed_comm;
        agg.idle += rep.idle;
    }
    // Fixed-order mean (replicate order): deterministic across thread
    // counts because one worker owns the whole replicate loop.
    let inv = 1.0 / n as f64;
    agg.iter_time *= inv;
    agg.compute_busy *= inv;
    agg.comm_busy *= inv;
    agg.comm_kernel_time *= inv;
    agg.exposed_comm *= inv;
    agg.idle *= inv;
    let metrics = metrics::from_report(cfg, &agg);
    (
        metrics,
        stats::percentile(&times, 50.0),
        stats::percentile(&times, 95.0),
        stats::percentile(&times, 99.0),
    )
}

/// A streamed/cancellable run was aborted by its cancellation flag.
/// Work already completed was committed to the store before the abort
/// (the store stays consistent); the assembled result is discarded.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Cancelled;

impl std::fmt::Display for Cancelled {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("request cancelled")
    }
}

/// A never-set flag for the plain (uncancellable) entry points.
static NO_CANCEL: AtomicBool = AtomicBool::new(false);

/// Executes studies with a shared simulation result store.
pub struct StudyRunner {
    threads: usize,
    /// Config-level dedup: `ConfigKey → CaseResult`, shared (and with
    /// a [`crate::store::LogStore`], persistent) across everything
    /// that holds the same `Arc`.
    store: Arc<dyn ResultStore>,
    evaluated: usize,
    requested: usize,
    pruned: usize,
    /// One long-lived arena per worker slot: the collective cost memo
    /// and all recycled buffers persist across waves, runs, and
    /// scenarios served by this runner.
    arenas: Vec<SimArena>,
    force_engine: bool,
}

impl StudyRunner {
    /// Runner with an explicit worker-thread count (min 1) and a
    /// private in-memory result store.
    pub fn new(threads: usize) -> StudyRunner {
        StudyRunner::with_store(threads, Arc::new(MemStore::new()))
    }

    /// Runner backed by an existing (possibly shared, possibly
    /// persistent) result store: the serve-mode constructor — every
    /// request gets a fresh runner over the process-wide store, so
    /// overlapping grids simulate only novel points.
    pub fn with_store(
        threads: usize,
        store: Arc<dyn ResultStore>,
    ) -> StudyRunner {
        StudyRunner {
            threads: threads.max(1),
            store,
            evaluated: 0,
            requested: 0,
            pruned: 0,
            arenas: Vec::new(),
            // Honor the debug env switch for runner-driven paths too.
            force_engine: SimArena::env_force_engine(),
        }
    }

    /// One worker per available core.
    pub fn auto() -> StudyRunner {
        let n = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4);
        StudyRunner::new(n)
    }

    /// Single-threaded runner (reference ordering / benchmarks).
    pub fn sequential() -> StudyRunner {
        StudyRunner::new(1)
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Route every simulation through the materialized event-graph
    /// engine instead of the fused fast path. Results are bit-identical
    /// either way (enforced by tests); this exists for debugging and
    /// for benchmarking the fast path against its reference.
    pub fn force_event_engine(&mut self, on: bool) {
        self.force_engine = on;
    }

    /// (simulations actually run, grid points requested) so far —
    /// the difference is what the store deduplicated and, for
    /// [`Self::best_of`], what the bound pruned.
    pub fn stats(&self) -> (usize, usize) {
        (self.evaluated, self.requested)
    }

    /// Hit/miss/size counters of the backing result store. With a
    /// shared store these are store-lifetime numbers, not per-runner:
    /// the runner performs exactly one counted lookup per distinct key
    /// per request (repeats within a request are resolved locally).
    pub fn store_stats(&self) -> StoreStats {
        self.store.stats()
    }

    /// Grid points skipped by [`Self::best_of`]'s analytic bound.
    pub fn pruned_points(&self) -> usize {
        self.pruned
    }

    /// Collective cost-memo (hits, misses), summed over the runner's
    /// persistent worker arenas.
    pub fn cost_cache_stats(&self) -> (u64, u64) {
        self.arenas.iter().fold((0, 0), |(h, m), a| {
            let (ah, am) = a.cost_stats();
            (h + ah, m + am)
        })
    }

    /// Expand and execute a study.
    pub fn run(&mut self, study: &Study) -> StudyResult {
        let points = study.expand();
        self.run_points(&study.name, &study.title, &points)
    }

    /// [`Self::run`] with serve-mode hooks: `emit` fires once per
    /// *novel* point (one this request actually simulated), in
    /// completion order, as soon as the point finishes; `cancel`
    /// aborts the remaining work at the next claim — completed points
    /// are already committed to the store, so a cancelled grid leaves
    /// the store consistent and a retry resumes where it stopped.
    pub fn run_streamed(
        &mut self,
        study: &Study,
        cancel: &AtomicBool,
        emit: impl FnMut(&CaseResult),
    ) -> Result<StudyResult, Cancelled> {
        let points = study.expand();
        self.run_points_streamed(
            &study.name,
            &study.title,
            &points,
            cancel,
            emit,
        )
    }

    /// Evaluate a single ad-hoc configuration through the cache. The
    /// memory footprint uses the planner's sharding/schedule-aware
    /// residency convention.
    pub fn eval(&mut self, cfg: &SimConfig) -> CaseResult {
        let mem = memory::per_gpu_memory_cfg(cfg);
        let point = StudyPoint { cfg: *cfg, mem_per_gpu: mem.total() };
        self.run_points("adhoc", "", &[point])
            .cases
            .pop()
            .expect("single point evaluates to single case")
    }

    fn run_points(
        &mut self,
        name: &str,
        title: &str,
        points: &[StudyPoint],
    ) -> StudyResult {
        self.run_points_streamed(name, title, points, &NO_CANCEL, |_| {})
            .expect("run without a cancel source cannot be cancelled")
    }

    fn run_points_streamed(
        &mut self,
        name: &str,
        title: &str,
        points: &[StudyPoint],
        cancel: &AtomicBool,
        mut emit: impl FnMut(&CaseResult),
    ) -> Result<StudyResult, Cancelled> {
        self.requested += points.len();

        // Store misses, deduplicated while preserving first-occurrence
        // order. Exactly one counted store lookup per distinct key:
        // in-request repeats resolve from the local `found` map, and
        // the final grid-order assembly below reads only `found` —
        // never the store — so hit/miss counters measure cross-request
        // sharing, not assembly traffic.
        let mut seen: HashSet<ConfigKey> = HashSet::new();
        let mut found: HashMap<ConfigKey, CaseResult> = HashMap::new();
        let mut todo: Vec<&StudyPoint> = Vec::new();
        for p in points {
            let key = ConfigKey::of(&p.cfg);
            if !seen.insert(key) {
                continue;
            }
            match self.store.get(&key) {
                Some(case) => {
                    found.insert(key, case);
                }
                None => todo.push(p),
            }
        }

        let keys: Vec<ConfigKey> =
            todo.iter().map(|p| ConfigKey::of(&p.cfg)).collect();
        let store = Arc::clone(&self.store);
        let mut newly = 0usize;
        let completed =
            self.evaluate_points_streamed(&todo, cancel, |i, case| {
                // Commit before emitting: whatever a client saw is
                // durable even if the request dies right after.
                store.put(keys[i], case.clone());
                emit(&case);
                found.insert(keys[i], case);
                newly += 1;
            });
        self.evaluated += newly;
        if !completed {
            return Err(Cancelled);
        }

        let cases = points
            .iter()
            .map(|p| {
                found
                    .get(&ConfigKey::of(&p.cfg))
                    .expect("every requested point evaluated")
                    .clone()
            })
            .collect();
        Ok(StudyResult {
            name: name.to_string(),
            title: title.to_string(),
            cases,
        })
    }

    /// The case `run(study)` + [`StudyResult::best`] would select,
    /// found by bound-and-prune instead of exhaustive simulation:
    /// candidates are evaluated in order of an optimistic analytic
    /// throughput bound ([`sim::iter_time_lower_bound`], ignoring all
    /// communication), and once some *achieved* throughput exceeds a
    /// candidate's bound, that candidate — and every one after it in
    /// bound order — is provably dominated and skipped.
    ///
    /// The search is parallel and **bound-sharing**: workers pull
    /// candidates off the sorted list through an atomic cursor, and
    /// every evaluated case publishes its achieved throughput into a
    /// shared `AtomicU64` (f64 bits; non-negative floats order like
    /// their bit patterns, so `fetch_max` is a lock-free running max).
    /// Each worker re-reads that bound before simulating, so one
    /// worker's improvement immediately tightens everyone's prune.
    /// Timing only affects *how many* dominated points get evaluated
    /// before the bound propagates — never the winner.
    ///
    /// Winner identity is exact, including `best`'s first-in-grid-order
    /// tie-break: the bound is safety-inflated so f64 rounding cannot
    /// disqualify a true winner, pruning requires the *strict* failure
    /// `bound <= incumbent`, a pruned candidate therefore cannot even
    /// tie the incumbent, and the final winner is folded from the
    /// evaluated + cached cases with the deterministic
    /// (max wps, lowest grid index) rule. Skipped points are reported
    /// via [`Self::pruned_points`].
    pub fn best_of(&mut self, study: &Study) -> Option<CaseResult> {
        self.best_of_by(study, Objective::MeanWps)
    }

    /// [`Self::best_of`] under an explicit [`Objective`] — e.g.
    /// `Objective::P95Wps` finds the configuration with the best
    /// tail-latency throughput over a seeded study. Same bound-and-prune
    /// machinery and the same exactness proof: the analytic bound
    /// `tokens / comm_free_lower_bound` dominates every objective's
    /// score because jitter can only slow an iteration down.
    pub fn best_of_by(
        &mut self,
        study: &Study,
        objective: Objective,
    ) -> Option<CaseResult> {
        self.best_of_by_cancellable(study, objective, &NO_CANCEL)
            .expect("search without a cancel source cannot be cancelled")
    }

    /// [`Self::best_of`] with per-request cancellation: the shared
    /// claim loop checks `cancel` before every claim, evaluated
    /// candidates are committed to the store even on abort, and a
    /// cancelled search returns `Err(Cancelled)` instead of a winner
    /// (a partial search cannot prove optimality). The
    /// `evaluated + pruned == requested` accounting identity holds
    /// only for searches that run to completion.
    pub fn best_of_cancellable(
        &mut self,
        study: &Study,
        cancel: &AtomicBool,
    ) -> Result<Option<CaseResult>, Cancelled> {
        self.best_of_by_cancellable(study, Objective::MeanWps, cancel)
    }

    /// [`Self::best_of_by`] with per-request cancellation — the full
    /// entry point the other three `best_of*` variants delegate to.
    pub fn best_of_by_cancellable(
        &mut self,
        study: &Study,
        objective: Objective,
        cancel: &AtomicBool,
    ) -> Result<Option<CaseResult>, Cancelled> {
        let points = study.expand();
        self.requested += points.len();
        if points.is_empty() {
            return Ok(None);
        }
        let keys: Vec<ConfigKey> =
            points.iter().map(|p| ConfigKey::of(&p.cfg)).collect();

        // Incumbent: (achieved wps, grid index), grid-order tie-break.
        // `raise` is a deterministic max-fold: the outcome is the same
        // whatever order candidates arrive in.
        let mut best: Option<(f64, usize)> = None;
        let raise = |wps: f64, idx: usize,
                     best: &mut Option<(f64, usize)>| {
            let replace = match *best {
                None => true,
                Some((bw, bi)) => wps > bw || (wps == bw && idx < bi),
            };
            if replace {
                *best = Some((wps, idx));
            }
        };

        // Store-known points are free: fold them into the incumbent
        // first and seed the shared bound with the best of them. One
        // counted store lookup per distinct key — in-request repeats
        // resolve from the local `known` map, where a duplicate's
        // `raise` is a provable no-op (equal wps, higher grid index).
        // The remainder is deduplicated by key (first occurrence keeps
        // its grid index, matching `best`'s tie-break).
        let mut known: HashMap<ConfigKey, CaseResult> = HashMap::new();
        let mut seen: HashSet<ConfigKey> = HashSet::new();
        let mut todo: Vec<(usize, f64)> = Vec::new(); // (grid idx, ub)
        for (idx, p) in points.iter().enumerate() {
            if let Some(case) = known.get(&keys[idx]) {
                raise(objective.score(case), idx, &mut best);
            } else if seen.insert(keys[idx]) {
                if let Some(case) = self.store.get(&keys[idx]) {
                    raise(objective.score(&case), idx, &mut best);
                    known.insert(keys[idx], case);
                } else {
                    // Deflating the time bound inflates the throughput
                    // bound, so rounding in the closed-form product
                    // can never undercut the engine's chained-addition
                    // result.
                    let lb =
                        sim::iter_time_lower_bound(&p.cfg) * (1.0 - 1e-9);
                    todo.push((idx, p.cfg.global_tokens() / lb));
                }
            }
        }
        // Most promising first; index-ascending on equal bounds keeps
        // the evaluation order deterministic.
        todo.sort_by(|a, b| {
            b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0))
        });

        // Shared best-known achieved throughput, as f64 bits (0.0 when
        // nothing is known yet — throughputs are strictly positive).
        let bound = AtomicU64::new(
            best.map_or(0.0f64, |(bw, _)| bw).to_bits());
        let slots: Vec<OnceLock<CaseResult>> =
            todo.iter().map(|_| OnceLock::new()).collect();
        let workers = self.prepare_workers(todo.len());
        let next = AtomicUsize::new(0);
        if workers == 1 {
            bound_search_loop(&next, &todo, &points, &slots, &bound,
                              cancel, objective, &mut self.arenas[0]);
        } else {
            std::thread::scope(|s| {
                let (next, todo, points, slots, bound) =
                    (&next, &todo[..], &points[..], &slots[..], &bound);
                for arena in self.arenas.iter_mut().take(workers) {
                    s.spawn(move || {
                        bound_search_loop(next, todo, points, slots,
                                          bound, cancel, objective,
                                          arena);
                    });
                }
            });
        }

        // Deterministic post-fold: harvest evaluated cases in candidate
        // order, commit them to the store, and let the max-fold pick
        // the winner. On a cancelled search the committed work is
        // kept (the store stays consistent) but empty slots are *not*
        // pruned points — they were simply never reached.
        let cancelled = cancel.load(Ordering::Relaxed);
        for (i, slot) in slots.into_iter().enumerate() {
            let idx = todo[i].0;
            match slot.into_inner() {
                Some(case) => {
                    self.evaluated += 1;
                    raise(objective.score(&case), idx, &mut best);
                    self.store.put(keys[idx], case.clone());
                    known.insert(keys[idx], case);
                }
                None if !cancelled => self.pruned += 1,
                None => {}
            }
        }
        if cancelled {
            return Err(Cancelled);
        }

        Ok(best.map(|(_, idx)| {
            known
                .get(&keys[idx])
                .expect("winning point is always known")
                .clone()
        }))
    }

    /// Evaluate all points, in parallel when `threads > 1`, invoking
    /// `on_case(input_index, case)` on the *calling* thread as each
    /// point completes (completion order; callers wanting input order
    /// index by `i`). Returns `true` when every point was evaluated,
    /// `false` when `cancel` stopped the work early.
    ///
    /// Each worker drives one of the runner's *persistent*
    /// `SimArena`s — grown once to the worker count and reused (never
    /// reallocated) across waves, runs, and scenarios, so the
    /// collective cost memo and recycled buffers persist.
    ///
    /// Scheduling is work-stealing over an atomic cursor with *chunked*
    /// claims: each grab takes a contiguous run of points sized so
    /// every worker makes ~8 claims total, amortizing the shared
    /// cache-line bump while still load-balancing heterogeneous grid
    /// points (a deep-pipeline point can cost 100× a pp = 1 point).
    /// The cancellation flag is checked per *point* (not per chunk),
    /// bounding post-cancel work to the points already in flight.
    fn evaluate_points_streamed(
        &mut self,
        points: &[&StudyPoint],
        cancel: &AtomicBool,
        mut on_case: impl FnMut(usize, CaseResult),
    ) -> bool {
        let workers = self.prepare_workers(points.len());
        if workers == 1 {
            let arena = &mut self.arenas[0];
            for (i, p) in points.iter().enumerate() {
                if cancel.load(Ordering::Relaxed) {
                    return false;
                }
                if crate::fault::point("runner.worker.panic") {
                    panic!(
                        "injected fault runner.worker.panic \
                         (at point claim {i})"
                    );
                }
                on_case(i, evaluate_point(p, arena));
            }
            return true;
        }
        // Workers stream completions over a channel; the calling
        // thread drains it inside the scope, so `on_case` (which may
        // write to a client socket) runs concurrently with evaluation
        // and needs no Sync bound.
        let next = AtomicUsize::new(0);
        let chunk = (points.len() / (workers * 8)).max(1);
        let (tx, rx) = mpsc::channel::<(usize, CaseResult)>();
        let mut delivered = 0usize;
        std::thread::scope(|s| {
            let next = &next;
            for arena in self.arenas.iter_mut().take(workers) {
                let tx = tx.clone();
                s.spawn(move || loop {
                    let start = next.fetch_add(chunk, Ordering::Relaxed);
                    if start >= points.len() {
                        break;
                    }
                    let end = (start + chunk).min(points.len());
                    for i in start..end {
                        if cancel.load(Ordering::Relaxed) {
                            return;
                        }
                        if crate::fault::point("runner.worker.panic") {
                            panic!(
                                "injected fault runner.worker.panic \
                                 (at point claim {i})"
                            );
                        }
                        let case = evaluate_point(points[i], arena);
                        if tx.send((i, case)).is_err() {
                            return;
                        }
                    }
                });
            }
            // The workers hold the only remaining senders: recv fails
            // exactly when all of them have finished or bailed.
            drop(tx);
            while let Ok((i, case)) = rx.recv() {
                delivered += 1;
                on_case(i, case);
            }
        });
        delivered == points.len()
    }

    /// Size the worker pool for `n` work items and make the persistent
    /// arenas ready: grow `self.arenas` to the worker count (once — the
    /// high-water mark is reused, never reallocated) and propagate the
    /// engine-forcing flag. The single worker-lifecycle path shared by
    /// [`Self::best_of`] and `evaluate_points_streamed`.
    fn prepare_workers(&mut self, n: usize) -> usize {
        let workers = if self.threads <= 1 || n <= 1 {
            1
        } else {
            self.threads.min(n)
        };
        while self.arenas.len() < workers {
            self.arenas.push(SimArena::new());
        }
        for arena in &mut self.arenas {
            arena.force_engine(self.force_engine);
        }
        workers
    }

    /// Worker arenas currently held (grown to the high-water worker
    /// count, then reused — regression guard for the per-call
    /// reallocation bug).
    pub fn worker_arenas(&self) -> usize {
        self.arenas.len()
    }

    /// Fused-path schedule-driver split `(steady, fallback)` summed
    /// over the runner's persistent worker arenas (see
    /// [`SimArena::steady_stats`]).
    pub fn steady_stats(&self) -> (u64, u64) {
        self.arenas.iter().fold((0, 0), |(a, b), ar| {
            let (s, g) = ar.steady_stats();
            (a + s, b + g)
        })
    }

    /// Interval-compression diagnostic `(intervals recorded, runs
    /// stored)` summed over the runner's worker arenas (see
    /// [`SimArena::interval_stats`]).
    pub fn interval_stats(&self) -> (u64, u64) {
        self.arenas.iter().fold((0, 0), |(a, b), ar| {
            let (r, k) = ar.interval_stats();
            (a + r, b + k)
        })
    }
}

/// Results of one study run, in grid-expansion order until sorted.
#[derive(Debug, Clone)]
pub struct StudyResult {
    pub name: String,
    pub title: String,
    pub cases: Vec<CaseResult>,
}

impl StudyResult {
    /// Stable sort by global throughput, best first (the planner's
    /// ranking; ties keep grid order).
    pub fn sort_by_wps(&mut self) {
        self.cases.sort_by(|a, b| {
            b.metrics
                .global_wps
                .partial_cmp(&a.metrics.global_wps)
                .expect("throughput is never NaN")
        });
    }

    /// Highest-throughput case (first on ties, matching a stable sort).
    pub fn best(&self) -> Option<&CaseResult> {
        self.best_by(Objective::MeanWps)
    }

    /// Highest-scoring case under an explicit [`Objective`] (first on
    /// ties, matching `best`'s grid-order tie-break) — the exhaustive
    /// reference [`StudyRunner::best_of_by`] must agree with.
    pub fn best_by(&self, objective: Objective) -> Option<&CaseResult> {
        let mut best: Option<(&CaseResult, f64)> = None;
        for c in &self.cases {
            let score = objective.score(c);
            let better = match best {
                None => true,
                Some((_, bs)) => score > bs,
            };
            if better {
                best = Some((c, score));
            }
        }
        best.map(|(c, _)| c)
    }

    /// Best case per key, keys in first-occurrence order (e.g. the
    /// optimal plan per cluster size: `best_per(|c| c.nodes)`). Keys
    /// are resolved through an order-preserving hash index — linear in
    /// the case count, not quadratic in distinct keys.
    pub fn best_per<K: Eq + Hash>(
        &self,
        key: impl Fn(&CaseResult) -> K,
    ) -> Vec<&CaseResult> {
        let mut index: HashMap<K, usize> = HashMap::new();
        let mut best: Vec<&CaseResult> = Vec::new();
        for c in &self.cases {
            match index.entry(key(c)) {
                Entry::Occupied(e) => {
                    let i = *e.get();
                    if c.metrics.global_wps > best[i].metrics.global_wps {
                        best[i] = c;
                    }
                }
                Entry::Vacant(e) => {
                    e.insert(best.len());
                    best.push(c);
                }
            }
        }
        best
    }

    pub fn retain(&mut self, f: impl FnMut(&CaseResult) -> bool) {
        self.cases.retain(f);
    }

    pub fn truncate(&mut self, n: usize) {
        self.cases.truncate(n);
    }

    /// Render with default column headers.
    pub fn table(&self, columns: &[Column]) -> Table {
        let headers: Vec<&str> =
            columns.iter().map(|c| c.header()).collect();
        self.table_renamed(&headers, columns)
    }

    /// Render with explicit headers (lengths must match).
    pub fn table_renamed(&self, headers: &[&str], columns: &[Column]) -> Table {
        assert_eq!(headers.len(), columns.len(),
                   "header/column count mismatch in {}", self.name);
        let mut t = Table::new(&self.name, &self.title, headers);
        for c in &self.cases {
            t.row(columns.iter().map(|col| col.cell(c)).collect());
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::LLAMA_7B;
    use crate::study::{PlanAxis, Study};

    fn small_sweep(name: &str) -> Study {
        Study::builder(name)
            .arch(LLAMA_7B)
            .nodes([2])
            .plans(PlanAxis::Sweep { with_cp: false })
            .global_batches([64])
            .micro_batch_divisors()
            .memory_cap(0.94)
            .build()
    }

    #[test]
    fn parallel_matches_sequential_order() {
        let study = small_sweep("order");
        let seq = StudyRunner::sequential().run(&study);
        let par = StudyRunner::new(8).run(&study);
        assert!(!seq.cases.is_empty());
        assert_eq!(seq.cases.len(), par.cases.len());
        for (a, b) in seq.cases.iter().zip(&par.cases) {
            assert_eq!(a.plan, b.plan);
            assert_eq!(a.micro_batch, b.micro_batch);
            assert_eq!(a.metrics.global_wps, b.metrics.global_wps);
        }
    }

    #[test]
    fn cache_deduplicates_repeat_runs() {
        let study = small_sweep("cache");
        let mut runner = StudyRunner::sequential();
        let first = runner.run(&study);
        let (evaluated, requested) = runner.stats();
        assert_eq!(evaluated, requested);
        assert_eq!(evaluated, first.cases.len());
        let second = runner.run(&study);
        let (evaluated2, requested2) = runner.stats();
        assert_eq!(evaluated2, evaluated, "second run must be all cache hits");
        assert_eq!(requested2, 2 * requested);
        assert_eq!(second.cases.len(), first.cases.len());
    }

    #[test]
    fn sort_and_best_agree() {
        let mut res = StudyRunner::sequential().run(&small_sweep("best"));
        let best_wps = res.best().unwrap().metrics.global_wps;
        res.sort_by_wps();
        assert_eq!(res.cases[0].metrics.global_wps, best_wps);
        for w in res.cases.windows(2) {
            assert!(w[0].metrics.global_wps >= w[1].metrics.global_wps);
        }
    }

    #[test]
    fn best_per_groups_in_first_occurrence_order() {
        let study = Study::builder("per-scale")
            .arch(LLAMA_7B)
            .nodes([1, 2, 4])
            .plans(PlanAxis::Sweep { with_cp: false })
            .global_batches([32])
            .micro_batch_divisors()
            .memory_cap(0.94)
            .build();
        let res = StudyRunner::sequential().run(&study);
        let winners = res.best_per(|c| c.nodes);
        let node_order: Vec<usize> = winners.iter().map(|c| c.nodes).collect();
        assert_eq!(node_order, vec![1, 2, 4]);
        for w in &winners {
            for c in res.cases.iter().filter(|c| c.nodes == w.nodes) {
                assert!(w.metrics.global_wps >= c.metrics.global_wps);
            }
        }
    }

    #[test]
    fn eval_caches_adhoc_configs() {
        let cfg = crate::sim::SimConfig::fsdp(
            LLAMA_7B,
            crate::topology::Cluster::new(crate::hardware::Generation::H100, 2),
            ParallelPlan::data_parallel(16),
            32, 2, 4096);
        let mut runner = StudyRunner::sequential();
        let a = runner.eval(&cfg);
        let b = runner.eval(&cfg);
        assert_eq!(runner.stats().0, 1);
        assert_eq!(a.metrics.global_wps, b.metrics.global_wps);
        assert!(a.mem_per_gpu > 0.0);
    }

    fn fake_case(nodes: usize, wps: f64) -> CaseResult {
        CaseResult {
            arch: "7b",
            hw: HwId::H100,
            nodes,
            plan: ParallelPlan::data_parallel(8),
            global_batch: 16,
            micro_batch: 2,
            seq_len: 4096,
            sharding: Sharding::Fsdp,
            schedule: Schedule::OneFOneB,
            sync: SyncMode::Sync,
            relia: Reliability::OFF,
            ckpt_bytes: 1e9,
            metrics: Metrics {
                iter_time: 1.0,
                global_wps: wps,
                per_gpu_wps: wps / 8.0,
                tflops_per_gpu: 1.0,
                mfu: 0.4,
                compute_time: 0.5,
                comm_time: 0.2,
                exposed_comm: 0.1,
                exposed_frac: 0.5,
                power_w: 600.0,
                total_power_w: 4800.0,
                wps_per_watt: wps / 4800.0,
                energy_per_token_j: 1.0,
                world: 8,
            },
            iter_p50: 1.0,
            iter_p95: 1.0,
            iter_p99: 1.0,
            mem_per_gpu: 1e9,
        }
    }

    #[test]
    fn best_per_scales_to_many_distinct_keys() {
        // 500 distinct keys × 3 rounds: the hash index must keep
        // first-occurrence order and pick each key's max.
        let n = 500usize;
        let mut cases = Vec::new();
        for round in 0..3usize {
            for k in 0..n {
                cases.push(fake_case(k, (round * n + k) as f64));
            }
        }
        let res = StudyResult {
            name: "many-keys".into(),
            title: String::new(),
            cases,
        };
        let winners = res.best_per(|c| c.nodes);
        assert_eq!(winners.len(), n);
        for (k, w) in winners.iter().enumerate() {
            assert_eq!(w.nodes, k, "first-occurrence order broken");
            assert_eq!(w.metrics.global_wps, (2 * n + k) as f64);
        }
    }

    #[test]
    fn best_of_matches_full_sweep_winner() {
        for nodes in [1usize, 2] {
            let study = Study::builder("prune")
                .arch(LLAMA_7B)
                .nodes([nodes])
                .plans(PlanAxis::Sweep { with_cp: false })
                .global_batches([64])
                .micro_batch_divisors()
                .memory_cap(0.94)
                .build();
            let full = StudyRunner::sequential().run(&study);
            let expect = full.best().unwrap();
            let mut runner = StudyRunner::sequential();
            let got = runner.best_of(&study).unwrap();
            assert_eq!(got.plan, expect.plan);
            assert_eq!(got.micro_batch, expect.micro_batch);
            assert_eq!(got.metrics.global_wps.to_bits(),
                       expect.metrics.global_wps.to_bits());
            let (evaluated, requested) = runner.stats();
            assert_eq!(evaluated + runner.pruned_points(), requested);
        }
    }

    #[test]
    fn best_of_matches_full_sweep_winner_on_interleaved_grid() {
        // Pruned-best exactness over a grid that includes interleaved
        // schedules and ZeRO-3: the schedule-aware lower bound must
        // stay sound, so the bound-and-prune winner (incl. tie-breaks)
        // is the exhaustive head bit-for-bit.
        let study = Study::builder("sched-prune")
            .arch(LLAMA_7B)
            .nodes([2])
            .plan_shapes(&[(1, 1, 1), (1, 2, 1), (1, 4, 1)])
            .global_batches([32])
            .micro_batch_divisors()
            .schedules([
                Schedule::OneFOneB,
                Schedule::Interleaved { v: 2 },
                Schedule::Interleaved { v: 4 },
            ])
            .shardings([Sharding::Fsdp, Sharding::Zero3])
            .memory_cap(0.94)
            .build();
        let full = StudyRunner::sequential().run(&study);
        assert!(full.cases.iter().any(
            |c| matches!(c.schedule, Schedule::Interleaved { .. })),
            "grid must actually contain interleaved points");
        let expect = full.best().unwrap();
        let mut runner = StudyRunner::sequential();
        let got = runner.best_of(&study).unwrap();
        assert_eq!(got.plan, expect.plan);
        assert_eq!(got.micro_batch, expect.micro_batch);
        assert_eq!(got.schedule, expect.schedule);
        assert_eq!(got.sharding, expect.sharding);
        assert_eq!(got.metrics.global_wps.to_bits(),
                   expect.metrics.global_wps.to_bits());
        let (evaluated, requested) = runner.stats();
        assert_eq!(evaluated + runner.pruned_points(), requested);
    }

    #[test]
    fn parallel_best_of_matches_full_sweep_winner() {
        // The bound-sharing parallel search may *evaluate* a
        // timing-dependent set of candidates, but the winner — incl.
        // the first-in-grid-order tie-break — must be bit-identical to
        // the exhaustive sweep's head on every thread count.
        let study = Study::builder("par-prune")
            .arch(LLAMA_7B)
            .nodes([2])
            .plans(PlanAxis::Sweep { with_cp: false })
            .global_batches([64])
            .micro_batch_divisors()
            .memory_cap(0.94)
            .build();
        let full = StudyRunner::sequential().run(&study);
        let expect = full.best().unwrap();
        for threads in [2usize, 4, 8] {
            let mut runner = StudyRunner::new(threads);
            let got = runner.best_of(&study).unwrap();
            assert_eq!(got.plan, expect.plan, "threads={threads}");
            assert_eq!(got.micro_batch, expect.micro_batch);
            assert_eq!(got.metrics.global_wps.to_bits(),
                       expect.metrics.global_wps.to_bits());
            let (evaluated, requested) = runner.stats();
            assert_eq!(evaluated + runner.pruned_points(), requested,
                       "threads={threads}");
        }
    }

    #[test]
    fn parallel_best_of_matches_on_the_schedule_grid() {
        // Same proof over interleaved/ZeRO-3 arms with 8 workers
        // sharing the bound.
        let study = Study::builder("par-sched-prune")
            .arch(LLAMA_7B)
            .nodes([2])
            .plan_shapes(&[(1, 1, 1), (1, 2, 1), (1, 4, 1)])
            .global_batches([32])
            .micro_batch_divisors()
            .schedules([
                Schedule::OneFOneB,
                Schedule::Interleaved { v: 2 },
            ])
            .shardings([Sharding::Fsdp, Sharding::Zero3])
            .memory_cap(0.94)
            .build();
        let full = StudyRunner::sequential().run(&study);
        let expect = full.best().unwrap();
        let mut runner = StudyRunner::new(8);
        let got = runner.best_of(&study).unwrap();
        assert_eq!(got.plan, expect.plan);
        assert_eq!(got.micro_batch, expect.micro_batch);
        assert_eq!(got.schedule, expect.schedule);
        assert_eq!(got.sharding, expect.sharding);
        assert_eq!(got.metrics.global_wps.to_bits(),
                   expect.metrics.global_wps.to_bits());
    }

    #[test]
    fn worker_arenas_grow_once_and_are_reused() {
        // Arenas are the runner's most expensive state (cost memo +
        // recycled buffers): repeated runs must not grow or replace
        // them — the cost-cache hit counter keeps climbing across runs
        // only if the same arenas serve every call.
        let study = small_sweep("arena-reuse");
        let mut runner = StudyRunner::new(4);
        runner.run(&study);
        let arenas = runner.worker_arenas();
        assert!(arenas >= 1 && arenas <= 4, "{arenas}");
        let (hits_before, misses_before) = runner.cost_cache_stats();
        runner.best_of(&study); // all cache hits: no new arenas either
        for _ in 0..3 {
            runner.run(&study);
        }
        assert_eq!(runner.worker_arenas(), arenas,
                   "repeat runs must reuse the same worker arenas");
        let (hits_after, misses_after) = runner.cost_cache_stats();
        assert_eq!(misses_after, misses_before,
                   "warm reruns must not re-derive collective costs");
        assert_eq!(hits_after, hits_before,
                   "warm reruns are config-cache hits, not re-sims");
    }

    #[test]
    fn runner_surfaces_compression_stats() {
        let mut runner = StudyRunner::sequential();
        runner.run(&small_sweep("compression-stats"));
        let (steady, fallback) = runner.steady_stats();
        assert!(steady > 0, "fig-style sweep must hit the wave driver");
        let (recorded, runs) = runner.interval_stats();
        assert!(recorded > 0 && runs > 0 && runs <= recorded);
        let _ = fallback; // may be 0 on an all-eligible grid
    }

    #[test]
    fn best_of_reuses_the_cache() {
        let study = small_sweep("prune-cache");
        let mut runner = StudyRunner::sequential();
        let full = runner.run(&study);
        let (evaluated, _) = runner.stats();
        let best = runner.best_of(&study).unwrap();
        let (evaluated2, _) = runner.stats();
        assert_eq!(evaluated2, evaluated,
                   "best_of after run must be all cache hits");
        assert_eq!(best.plan, full.best().unwrap().plan);
    }

    #[test]
    fn forced_engine_matches_fast_path_bitwise() {
        let study = small_sweep("engine-vs-fused");
        let fast = StudyRunner::sequential().run(&study);
        let mut engine_runner = StudyRunner::sequential();
        engine_runner.force_event_engine(true);
        let slow = engine_runner.run(&study);
        assert_eq!(fast.cases.len(), slow.cases.len());
        for (a, b) in fast.cases.iter().zip(&slow.cases) {
            assert_eq!(a.metrics.global_wps.to_bits(),
                       b.metrics.global_wps.to_bits());
            assert_eq!(a.metrics.exposed_comm.to_bits(),
                       b.metrics.exposed_comm.to_bits());
            assert_eq!(a.metrics.iter_time.to_bits(),
                       b.metrics.iter_time.to_bits());
        }
    }

    #[test]
    fn cost_cache_stats_accumulate() {
        let mut runner = StudyRunner::sequential();
        runner.run(&small_sweep("cost-stats"));
        let (hits, misses) = runner.cost_cache_stats();
        assert!(misses > 0, "sweep must query the collective memo");
        assert!(hits > 0, "neighboring grid points must share costs");
    }

    #[test]
    fn shared_store_deduplicates_across_runners() {
        // The serve-mode contract: two runners over one store (two
        // requests against one process) simulate only novel points,
        // and the warm answer is bitwise the cold one.
        let study = small_sweep("shared-store");
        let store: Arc<dyn ResultStore> = Arc::new(MemStore::new());
        let mut cold =
            StudyRunner::with_store(1, Arc::clone(&store));
        let first = cold.run(&study);
        let distinct = cold.stats().0;
        assert!(distinct > 0);

        let mut warm =
            StudyRunner::with_store(1, Arc::clone(&store));
        let second = warm.run(&study);
        assert_eq!(warm.stats().0, 0,
                   "second runner must answer entirely from the store");
        assert_eq!(second.cases.len(), first.cases.len());
        for (a, b) in first.cases.iter().zip(&second.cases) {
            assert_eq!(a.metrics.global_wps.to_bits(),
                       b.metrics.global_wps.to_bits());
            assert_eq!(a.metrics.iter_time.to_bits(),
                       b.metrics.iter_time.to_bits());
            assert_eq!(a.mem_per_gpu.to_bits(), b.mem_per_gpu.to_bits());
        }

        let s = store.stats();
        assert_eq!(s.entries, distinct);
        assert_eq!(s.misses, distinct as u64,
                   "cold run: one counted miss per distinct key");
        assert_eq!(s.hits, distinct as u64,
                   "warm run: one counted hit per distinct key");
    }

    #[test]
    fn streamed_emit_fires_once_per_novel_point() {
        let study = small_sweep("stream-emit");
        let mut runner = StudyRunner::sequential();
        let mut emitted = 0usize;
        let res = runner
            .run_streamed(&study, &AtomicBool::new(false), |_| {
                emitted += 1;
            })
            .expect("uncancelled run completes");
        assert_eq!(emitted, runner.stats().0,
                   "one emit per simulated point");
        assert_eq!(res.cases.len(), study.expand().len());

        // A warm streamed rerun emits nothing: every point is a hit.
        let mut emitted2 = 0usize;
        runner
            .run_streamed(&study, &AtomicBool::new(false), |_| {
                emitted2 += 1;
            })
            .expect("warm run completes");
        assert_eq!(emitted2, 0);
    }

    #[test]
    fn cancelled_run_commits_partial_results_consistently() {
        let study = small_sweep("cancel-consistency");
        let total = StudyRunner::sequential().run(&study).cases.len();
        assert!(total > 3, "sweep too small to cancel mid-way");

        let store: Arc<dyn ResultStore> = Arc::new(MemStore::new());
        let cancel = AtomicBool::new(false);
        let stop_after = 3usize;
        let mut done = 0usize;
        let mut runner =
            StudyRunner::with_store(1, Arc::clone(&store));
        let res = runner.run_streamed(&study, &cancel, |_| {
            done += 1;
            if done == stop_after {
                cancel.store(true, Ordering::Relaxed);
            }
        });
        assert_eq!(res.unwrap_err(), Cancelled);
        assert_eq!(store.stats().entries, stop_after,
                   "every emitted point is already committed");

        // A retry over the same store resumes where the cancelled
        // request stopped and the final answer is bitwise identical to
        // a clean-store run.
        let mut retry =
            StudyRunner::with_store(1, Arc::clone(&store));
        let resumed = retry.run(&study);
        assert_eq!(retry.stats().0, total - stop_after,
                   "retry must simulate only the missing points");
        let clean = StudyRunner::sequential().run(&study);
        assert_eq!(resumed.cases.len(), clean.cases.len());
        for (a, b) in resumed.cases.iter().zip(&clean.cases) {
            assert_eq!(a.metrics.global_wps.to_bits(),
                       b.metrics.global_wps.to_bits());
            assert_eq!(a.metrics.exposed_comm.to_bits(),
                       b.metrics.exposed_comm.to_bits());
        }
    }

    #[test]
    fn best_of_rides_the_shared_store() {
        // Plan requests skip already-known points: a best_of after a
        // full sweep on a *different* runner sharing the store must
        // evaluate nothing and still return the exhaustive winner.
        let study = small_sweep("plan-shared-store");
        let store: Arc<dyn ResultStore> = Arc::new(MemStore::new());
        let mut sweeper =
            StudyRunner::with_store(1, Arc::clone(&store));
        let full = sweeper.run(&study);
        let expect = full.best().unwrap();

        let mut planner =
            StudyRunner::with_store(1, Arc::clone(&store));
        let got = planner.best_of(&study).unwrap();
        assert_eq!(planner.stats().0, 0,
                   "plan over a warm store must not simulate");
        assert_eq!(got.plan, expect.plan);
        assert_eq!(got.micro_batch, expect.micro_batch);
        assert_eq!(got.metrics.global_wps.to_bits(),
                   expect.metrics.global_wps.to_bits());
    }

    /// `small_sweep` with the straggler axis armed: same grid, every
    /// point evaluated as `reps` seeded lognormal replicates.
    fn seeded_sweep(name: &str, seed: u64, reps: u32) -> Study {
        Study::builder(name)
            .arch(LLAMA_7B)
            .nodes([2])
            .plans(PlanAxis::Sweep { with_cp: false })
            .global_batches([64])
            .micro_batch_divisors()
            .memory_cap(0.94)
            .jitter(crate::sim::JitterDist::Lognormal { sigma: 0.2 })
            .seed(seed)
            .seeds(reps)
            .build()
    }

    #[test]
    fn unarmed_percentiles_are_the_deterministic_point_mass() {
        // jitter=off: the distribution is a point mass at the
        // deterministic run, so every percentile equals iter_time
        // bitwise and the p95 objective scores exactly like the mean
        // objective — the exactness contract the store/codec and the
        // golden figures rely on.
        let res =
            StudyRunner::sequential().run(&small_sweep("point-mass"));
        assert!(!res.cases.is_empty());
        for c in &res.cases {
            let t = c.metrics.iter_time.to_bits();
            assert_eq!(c.iter_p50.to_bits(), t);
            assert_eq!(c.iter_p95.to_bits(), t);
            assert_eq!(c.iter_p99.to_bits(), t);
            assert_eq!(Objective::P95Wps.score(c).to_bits(),
                       Objective::MeanWps.score(c).to_bits());
        }
    }

    #[test]
    fn seeded_replicates_report_ordered_percentiles() {
        let det = StudyRunner::sequential().run(&small_sweep("det-ref"));
        let res =
            StudyRunner::sequential().run(&seeded_sweep("dist", 7, 16));
        assert_eq!(det.cases.len(), res.cases.len());
        let mut spread = false;
        for (d, c) in det.cases.iter().zip(&res.cases) {
            assert!(c.iter_p50 <= c.iter_p95 && c.iter_p95 <= c.iter_p99,
                    "percentiles must be ordered");
            // Slowdown factors are clamped at 1: no replicate — hence
            // no percentile — beats the deterministic run.
            assert!(c.iter_p50 >= d.metrics.iter_time,
                    "{} < {}", c.iter_p50, d.metrics.iter_time);
            if c.iter_p99 > c.iter_p50 {
                spread = true;
            }
        }
        assert!(spread, "a seeded grid must show nonzero spread");
    }

    #[test]
    fn seeded_grid_replays_identically_across_thread_counts() {
        let study = seeded_sweep("replay", 7, 8);
        let a = StudyRunner::sequential().run(&study);
        let b = StudyRunner::new(8).run(&study);
        assert_eq!(a.cases.len(), b.cases.len());
        for (x, y) in a.cases.iter().zip(&b.cases) {
            assert_eq!(x.iter_p50.to_bits(), y.iter_p50.to_bits());
            assert_eq!(x.iter_p95.to_bits(), y.iter_p95.to_bits());
            assert_eq!(x.iter_p99.to_bits(), y.iter_p99.to_bits());
            assert_eq!(x.metrics.global_wps.to_bits(),
                       y.metrics.global_wps.to_bits());
        }
        // A different base seed is a different distribution.
        let c =
            StudyRunner::sequential().run(&seeded_sweep("replay-b", 8, 8));
        assert!(a.cases.iter().zip(&c.cases).any(
            |(x, y)| x.iter_p95.to_bits() != y.iter_p95.to_bits()),
            "seed 7 and seed 8 grids must diverge somewhere");
    }

    #[test]
    fn store_never_conflates_distinct_seed_points() {
        // Regression for the ConfigKey seed axis: same grid at two
        // seeds must simulate twice; the same seed again is pure hits
        // and replays bitwise.
        let store: Arc<dyn ResultStore> = Arc::new(MemStore::new());
        let mut runner = StudyRunner::with_store(1, Arc::clone(&store));
        let a = runner.run(&seeded_sweep("seed-a", 7, 4));
        let evaluated = runner.stats().0;
        assert!(evaluated > 0);
        runner.run(&seeded_sweep("seed-b", 8, 4));
        assert_eq!(runner.stats().0, 2 * evaluated,
                   "a different seed must simulate fresh points");
        let a2 = runner.run(&seeded_sweep("seed-a-again", 7, 4));
        assert_eq!(runner.stats().0, 2 * evaluated,
                   "the same seed must answer from the store");
        for (x, y) in a.cases.iter().zip(&a2.cases) {
            assert_eq!(x.iter_p95.to_bits(), y.iter_p95.to_bits());
            assert_eq!(x.metrics.global_wps.to_bits(),
                       y.metrics.global_wps.to_bits());
        }
    }

    #[test]
    fn p95_best_of_matches_exhaustive_on_a_seeded_grid() {
        // Winner identity for the quantile objective: bound-and-prune
        // under P95Wps must reproduce the exhaustive sweep's best_by
        // winner — plan, schedule, and score bits — at every thread
        // count, with the accounting identity intact.
        let study = seeded_sweep("p95-prune", 11, 8);
        let full = StudyRunner::sequential().run(&study);
        let expect = full.best_by(Objective::P95Wps).unwrap();
        for threads in [1usize, 4] {
            let mut runner = StudyRunner::new(threads);
            let got =
                runner.best_of_by(&study, Objective::P95Wps).unwrap();
            assert_eq!(got.plan, expect.plan, "threads={threads}");
            assert_eq!(got.micro_batch, expect.micro_batch);
            assert_eq!(got.iter_p95.to_bits(), expect.iter_p95.to_bits());
            assert_eq!(got.metrics.global_wps.to_bits(),
                       expect.metrics.global_wps.to_bits());
            let (evaluated, requested) = runner.stats();
            assert_eq!(evaluated + runner.pruned_points(), requested,
                       "threads={threads}");
        }
    }

    fn goodput_sweep(name: &str) -> Study {
        use crate::sim::CkptInterval;
        Study::builder(name)
            .arch(LLAMA_7B)
            .nodes([2])
            .plans(PlanAxis::Sweep { with_cp: false })
            .global_batches([64])
            .micro_batch_divisors()
            .memory_cap(0.94)
            .checkpoint(CkptInterval::Auto)
            .mtbf_override(200.0) // harsh fleet: discounts visibly vary
            .build()
    }

    #[test]
    fn goodput_best_of_matches_exhaustive_on_an_armed_grid() {
        // Winner identity for the availability-discounted objective:
        // bound-and-prune under GoodputWps must reproduce the
        // exhaustive sweep's best_by winner — plan, schedule, and
        // score bits — at every thread count (sound because the
        // discount only lowers scores below the raw-throughput bound).
        let study = goodput_sweep("goodput-prune");
        let full = StudyRunner::sequential().run(&study);
        let expect = full.best_by(Objective::GoodputWps).unwrap();
        // The discount is real on this grid: the armed score is
        // strictly below the raw throughput somewhere.
        assert!(full.cases.iter().any(
            |c| c.goodput_wps() < c.metrics.global_wps));
        for threads in [1usize, 4] {
            let mut runner = StudyRunner::new(threads);
            let got =
                runner.best_of_by(&study, Objective::GoodputWps).unwrap();
            assert_eq!(got.plan, expect.plan, "threads={threads}");
            assert_eq!(got.micro_batch, expect.micro_batch);
            assert_eq!(got.goodput_wps().to_bits(),
                       expect.goodput_wps().to_bits());
            let (evaluated, requested) = runner.stats();
            assert_eq!(evaluated + runner.pruned_points(), requested,
                       "threads={threads}");
        }
    }

    #[test]
    fn goodput_objective_is_mean_wps_when_axis_off() {
        // Unarmed grids score bitwise-identically under GoodputWps and
        // MeanWps — the discount factor is exactly 1.0.
        let full = StudyRunner::sequential().run(&small_sweep("g-off"));
        for c in &full.cases {
            assert_eq!(Objective::GoodputWps.score(c).to_bits(),
                       Objective::MeanWps.score(c).to_bits());
        }
    }

    #[test]
    fn parallel_streamed_run_matches_sequential() {
        // The channel-streaming multi-worker path must deliver every
        // point exactly once and assemble the same grid-order result.
        let study = small_sweep("par-stream");
        let seq = StudyRunner::sequential().run(&study);
        let mut runner = StudyRunner::new(8);
        let mut emitted = 0usize;
        let par = runner
            .run_streamed(&study, &AtomicBool::new(false), |_| {
                emitted += 1;
            })
            .expect("uncancelled run completes");
        assert_eq!(emitted, runner.stats().0);
        assert_eq!(par.cases.len(), seq.cases.len());
        for (a, b) in seq.cases.iter().zip(&par.cases) {
            assert_eq!(a.plan, b.plan);
            assert_eq!(a.metrics.global_wps.to_bits(),
                       b.metrics.global_wps.to_bits());
        }
    }
}
