//! Study execution: expands a grid, skips configurations already
//! simulated (keyed by [`ConfigKey`]), and evaluates the remainder
//! across scoped worker threads.
//!
//! Determinism: results are assembled in grid-expansion order and every
//! sort downstream is stable, so a run with 1 thread and a run with N
//! threads produce byte-identical tables. The cache makes figure
//! regeneration cheap too — the weak-scaling configs, for example, are
//! shared by Fig. 1, Fig. 3, and the headline table, and are simulated
//! exactly once per `StudyRunner`.

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::hardware::Generation;
use crate::memory;
use crate::metrics::{self, Metrics};
use crate::parallelism::ParallelPlan;
use crate::sim::{Sharding, SimConfig};

use super::table::{Column, Table};
use super::{ConfigKey, Study, StudyPoint};

/// One simulated grid point with its full metric set.
#[derive(Debug, Clone)]
pub struct CaseResult {
    pub arch: &'static str,
    pub gen: Generation,
    pub nodes: usize,
    pub plan: ParallelPlan,
    pub global_batch: usize,
    pub micro_batch: usize,
    pub seq_len: usize,
    pub sharding: Sharding,
    pub metrics: Metrics,
    pub mem_per_gpu: f64,
}

fn evaluate_point(p: &StudyPoint) -> CaseResult {
    CaseResult {
        arch: p.cfg.arch.name,
        gen: p.cfg.cluster.node.gpu,
        nodes: p.cfg.cluster.nodes,
        plan: p.cfg.plan,
        global_batch: p.cfg.global_batch,
        micro_batch: p.cfg.micro_batch,
        seq_len: p.cfg.seq_len,
        sharding: p.cfg.sharding,
        metrics: metrics::evaluate(&p.cfg),
        mem_per_gpu: p.mem_per_gpu,
    }
}

/// Executes studies with a shared simulation cache.
pub struct StudyRunner {
    threads: usize,
    cache: HashMap<ConfigKey, CaseResult>,
    evaluated: usize,
    requested: usize,
}

impl StudyRunner {
    /// Runner with an explicit worker-thread count (min 1).
    pub fn new(threads: usize) -> StudyRunner {
        StudyRunner {
            threads: threads.max(1),
            cache: HashMap::new(),
            evaluated: 0,
            requested: 0,
        }
    }

    /// One worker per available core.
    pub fn auto() -> StudyRunner {
        let n = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4);
        StudyRunner::new(n)
    }

    /// Single-threaded runner (reference ordering / benchmarks).
    pub fn sequential() -> StudyRunner {
        StudyRunner::new(1)
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// (simulations actually run, grid points requested) so far —
    /// the difference is what the cache deduplicated.
    pub fn stats(&self) -> (usize, usize) {
        (self.evaluated, self.requested)
    }

    /// Expand and execute a study.
    pub fn run(&mut self, study: &Study) -> StudyResult {
        let points = study.expand();
        self.run_points(&study.name, &study.title, &points)
    }

    /// Evaluate a single ad-hoc configuration through the cache. The
    /// memory footprint uses the planner's in-flight-microbatch
    /// convention.
    pub fn eval(&mut self, cfg: &SimConfig) -> CaseResult {
        let in_flight = cfg.microbatches().min(cfg.plan.pp);
        let mem = memory::per_gpu_memory(
            &cfg.arch, &cfg.plan, cfg.micro_batch, cfg.seq_len, in_flight);
        let point = StudyPoint { cfg: *cfg, mem_per_gpu: mem.total() };
        self.run_points("adhoc", "", &[point])
            .cases
            .pop()
            .expect("single point evaluates to single case")
    }

    fn run_points(
        &mut self,
        name: &str,
        title: &str,
        points: &[StudyPoint],
    ) -> StudyResult {
        self.requested += points.len();

        // Unique cache misses, preserving first-occurrence order.
        let mut seen: HashSet<ConfigKey> = HashSet::new();
        let mut todo: Vec<&StudyPoint> = Vec::new();
        for p in points {
            let key = ConfigKey::of(&p.cfg);
            if !self.cache.contains_key(&key) && seen.insert(key) {
                todo.push(p);
            }
        }
        self.evaluated += todo.len();

        let keys: Vec<ConfigKey> =
            todo.iter().map(|p| ConfigKey::of(&p.cfg)).collect();
        let fresh = evaluate_all(&todo, self.threads);
        for (key, case) in keys.into_iter().zip(fresh) {
            self.cache.insert(key, case);
        }

        let cases = points
            .iter()
            .map(|p| {
                self.cache
                    .get(&ConfigKey::of(&p.cfg))
                    .expect("every requested point evaluated")
                    .clone()
            })
            .collect();
        StudyResult {
            name: name.to_string(),
            title: title.to_string(),
            cases,
        }
    }
}

/// Evaluate all points, in parallel when `threads > 1`. Output order
/// matches input order.
fn evaluate_all(points: &[&StudyPoint], threads: usize) -> Vec<CaseResult> {
    if threads <= 1 || points.len() <= 1 {
        return points.iter().map(|p| evaluate_point(p)).collect();
    }
    let slots: Vec<Mutex<Option<CaseResult>>> =
        points.iter().map(|_| Mutex::new(None)).collect();
    let next = AtomicUsize::new(0);
    let workers = threads.min(points.len());
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= points.len() {
                    break;
                }
                let case = evaluate_point(points[i]);
                *slots[i].lock().unwrap() = Some(case);
            });
        }
    });
    slots
        .into_iter()
        .map(|m| {
            m.into_inner()
                .expect("worker thread poisoned a result slot")
                .expect("every slot filled by the work loop")
        })
        .collect()
}

/// Results of one study run, in grid-expansion order until sorted.
#[derive(Debug, Clone)]
pub struct StudyResult {
    pub name: String,
    pub title: String,
    pub cases: Vec<CaseResult>,
}

impl StudyResult {
    /// Stable sort by global throughput, best first (the planner's
    /// ranking; ties keep grid order).
    pub fn sort_by_wps(&mut self) {
        self.cases.sort_by(|a, b| {
            b.metrics
                .global_wps
                .partial_cmp(&a.metrics.global_wps)
                .expect("throughput is never NaN")
        });
    }

    /// Highest-throughput case (first on ties, matching a stable sort).
    pub fn best(&self) -> Option<&CaseResult> {
        let mut best: Option<&CaseResult> = None;
        for c in &self.cases {
            let better = match best {
                None => true,
                Some(b) => c.metrics.global_wps > b.metrics.global_wps,
            };
            if better {
                best = Some(c);
            }
        }
        best
    }

    /// Best case per key, keys in first-occurrence order (e.g. the
    /// optimal plan per cluster size: `best_per(|c| c.nodes)`).
    pub fn best_per<K: PartialEq>(
        &self,
        key: impl Fn(&CaseResult) -> K,
    ) -> Vec<&CaseResult> {
        let mut keys: Vec<K> = Vec::new();
        let mut best: Vec<&CaseResult> = Vec::new();
        for c in &self.cases {
            let k = key(c);
            match keys.iter().position(|existing| *existing == k) {
                Some(i) => {
                    if c.metrics.global_wps > best[i].metrics.global_wps {
                        best[i] = c;
                    }
                }
                None => {
                    keys.push(k);
                    best.push(c);
                }
            }
        }
        best
    }

    pub fn retain(&mut self, f: impl FnMut(&CaseResult) -> bool) {
        self.cases.retain(f);
    }

    pub fn truncate(&mut self, n: usize) {
        self.cases.truncate(n);
    }

    /// Render with default column headers.
    pub fn table(&self, columns: &[Column]) -> Table {
        let headers: Vec<&str> =
            columns.iter().map(|c| c.header()).collect();
        self.table_renamed(&headers, columns)
    }

    /// Render with explicit headers (lengths must match).
    pub fn table_renamed(&self, headers: &[&str], columns: &[Column]) -> Table {
        assert_eq!(headers.len(), columns.len(),
                   "header/column count mismatch in {}", self.name);
        let mut t = Table::new(&self.name, &self.title, headers);
        for c in &self.cases {
            t.row(columns.iter().map(|col| col.cell(c)).collect());
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::LLAMA_7B;
    use crate::study::{PlanAxis, Study};

    fn small_sweep(name: &str) -> Study {
        Study::builder(name)
            .arch(LLAMA_7B)
            .nodes([2])
            .plans(PlanAxis::Sweep { with_cp: false })
            .global_batches([64])
            .micro_batch_divisors()
            .memory_cap(0.94)
            .build()
    }

    #[test]
    fn parallel_matches_sequential_order() {
        let study = small_sweep("order");
        let seq = StudyRunner::sequential().run(&study);
        let par = StudyRunner::new(8).run(&study);
        assert!(!seq.cases.is_empty());
        assert_eq!(seq.cases.len(), par.cases.len());
        for (a, b) in seq.cases.iter().zip(&par.cases) {
            assert_eq!(a.plan, b.plan);
            assert_eq!(a.micro_batch, b.micro_batch);
            assert_eq!(a.metrics.global_wps, b.metrics.global_wps);
        }
    }

    #[test]
    fn cache_deduplicates_repeat_runs() {
        let study = small_sweep("cache");
        let mut runner = StudyRunner::sequential();
        let first = runner.run(&study);
        let (evaluated, requested) = runner.stats();
        assert_eq!(evaluated, requested);
        assert_eq!(evaluated, first.cases.len());
        let second = runner.run(&study);
        let (evaluated2, requested2) = runner.stats();
        assert_eq!(evaluated2, evaluated, "second run must be all cache hits");
        assert_eq!(requested2, 2 * requested);
        assert_eq!(second.cases.len(), first.cases.len());
    }

    #[test]
    fn sort_and_best_agree() {
        let mut res = StudyRunner::sequential().run(&small_sweep("best"));
        let best_wps = res.best().unwrap().metrics.global_wps;
        res.sort_by_wps();
        assert_eq!(res.cases[0].metrics.global_wps, best_wps);
        for w in res.cases.windows(2) {
            assert!(w[0].metrics.global_wps >= w[1].metrics.global_wps);
        }
    }

    #[test]
    fn best_per_groups_in_first_occurrence_order() {
        let study = Study::builder("per-scale")
            .arch(LLAMA_7B)
            .nodes([1, 2, 4])
            .plans(PlanAxis::Sweep { with_cp: false })
            .global_batches([32])
            .micro_batch_divisors()
            .memory_cap(0.94)
            .build();
        let res = StudyRunner::sequential().run(&study);
        let winners = res.best_per(|c| c.nodes);
        let node_order: Vec<usize> = winners.iter().map(|c| c.nodes).collect();
        assert_eq!(node_order, vec![1, 2, 4]);
        for w in &winners {
            for c in res.cases.iter().filter(|c| c.nodes == w.nodes) {
                assert!(w.metrics.global_wps >= c.metrics.global_wps);
            }
        }
    }

    #[test]
    fn eval_caches_adhoc_configs() {
        let cfg = crate::sim::SimConfig::fsdp(
            LLAMA_7B,
            crate::topology::Cluster::new(crate::hardware::Generation::H100, 2),
            ParallelPlan::data_parallel(16),
            32, 2, 4096);
        let mut runner = StudyRunner::sequential();
        let a = runner.eval(&cfg);
        let b = runner.eval(&cfg);
        assert_eq!(runner.stats().0, 1);
        assert_eq!(a.metrics.global_wps, b.metrics.global_wps);
        assert!(a.mem_per_gpu > 0.0);
    }
}
