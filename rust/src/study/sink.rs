//! Result sinks: one interface for emitting rendered tables to the
//! console, CSV files, or JSON files. The figure harness and the
//! `dtsim study` CLI compose these instead of hardcoding output paths.

use std::path::{Path, PathBuf};

use crate::util::json::escape;

use super::table::Table;

/// Something a rendered table can be written to.
pub trait Sink {
    fn emit(&mut self, table: &Table) -> std::io::Result<()>;
}

/// Writes `<dir>/<table name>.csv` (the harness's historical format —
/// bytes are identical to the pre-Study writer).
pub struct CsvSink {
    dir: PathBuf,
}

impl CsvSink {
    pub fn new(dir: impl Into<PathBuf>) -> CsvSink {
        CsvSink { dir: dir.into() }
    }
}

impl Sink for CsvSink {
    fn emit(&mut self, table: &Table) -> std::io::Result<()> {
        std::fs::create_dir_all(&self.dir)?;
        table.write_csv(&self.dir)
    }
}

/// Writes `<dir>/<table name>.json`:
/// `{"name", "title", "header": [...], "rows": [[...], ...]}`.
pub struct JsonSink {
    dir: PathBuf,
}

impl JsonSink {
    pub fn new(dir: impl Into<PathBuf>) -> JsonSink {
        JsonSink { dir: dir.into() }
    }

    fn render(table: &Table) -> String {
        let strings = |fields: &[String]| {
            fields
                .iter()
                .map(|f| format!("\"{}\"", escape(f)))
                .collect::<Vec<_>>()
                .join(",")
        };
        let rows = table
            .rows
            .iter()
            .map(|r| format!("[{}]", strings(r)))
            .collect::<Vec<_>>()
            .join(",");
        format!(
            "{{\"name\":\"{}\",\"title\":\"{}\",\"header\":[{}],\"rows\":[{}]}}\n",
            escape(&table.name),
            escape(&table.title),
            strings(&table.header),
            rows
        )
    }
}

impl Sink for JsonSink {
    fn emit(&mut self, table: &Table) -> std::io::Result<()> {
        std::fs::create_dir_all(&self.dir)?;
        let path: &Path = &self.dir.join(format!("{}.json", table.name));
        std::fs::write(path, Self::render(table))
    }
}

/// Prints the aligned text table (+ optional ASCII chart) to stdout.
pub struct ConsoleSink;

impl Sink for ConsoleSink {
    fn emit(&mut self, table: &Table) -> std::io::Result<()> {
        table.print();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    fn sample() -> Table {
        let mut t = Table::new("sink_test", "a \"title\"", &["plan", "wps"]);
        t.row(vec!["dp8".into(), "1234".into()]);
        t.row(vec!["tp2,x".into(), "5678".into()]);
        t
    }

    #[test]
    fn csv_sink_matches_table_writer() {
        let dir = std::env::temp_dir().join("dtsim_sink_csv");
        CsvSink::new(&dir).emit(&sample()).unwrap();
        let text =
            std::fs::read_to_string(dir.join("sink_test.csv")).unwrap();
        assert_eq!(text, "plan,wps\ndp8,1234\n\"tp2,x\",5678\n");
    }

    #[test]
    fn json_sink_emits_parseable_json() {
        let dir = std::env::temp_dir().join("dtsim_sink_json");
        JsonSink::new(&dir).emit(&sample()).unwrap();
        let text =
            std::fs::read_to_string(dir.join("sink_test.json")).unwrap();
        let v = Json::parse(&text).unwrap();
        assert_eq!(v.get("name").unwrap().as_str().unwrap(), "sink_test");
        assert_eq!(v.get("title").unwrap().as_str().unwrap(), "a \"title\"");
        let rows = v.get("rows").unwrap().as_array().unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[1].as_array().unwrap()[0].as_str().unwrap(), "tp2,x");
    }

    #[test]
    fn console_sink_is_infallible() {
        ConsoleSink.emit(&sample()).unwrap();
    }
}
