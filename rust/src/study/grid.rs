//! Flag-driven grid and config construction, shared by the CLI and
//! serve mode.
//!
//! These builders used to live in `main.rs`; serve mode needs the same
//! `--grid`-style axis vocabulary for `study-grid` and `simulate`
//! requests (a request object's fields are just flags by another
//! transport), so the parsing moved into the library. Errors are plain
//! `String`s — the CLI wraps them in `anyhow`, the server ships them
//! as `error` events — and every parser's message enumerates the
//! accepted forms (the `parse_hw` / `parse_sharding` convention).

use crate::config::RunConfig;
use crate::hardware::HwId;
use crate::model::TransformerArch;
use crate::parallelism::ParallelPlan;
use crate::sim::{CkptInterval, Jitter, Reliability, Schedule, Sharding,
                 SimConfig, SyncMode};
use crate::topology::Cluster;
use crate::util::args::Args;

use super::{PlanAxis, Study};

/// Hardware-name parsing for `--gen`: built-ins plus anything loaded
/// via `--catalog`; the error enumerates every accepted form.
pub fn parse_hw(s: &str) -> Result<HwId, String> {
    HwId::parse(s).map_err(|e| format!("--gen: {e}"))
}

pub fn parse_sharding(s: &str) -> Result<Sharding, String> {
    crate::config::parse_sharding(s).map_err(|e| format!("--sharding: {e}"))
}

pub fn parse_schedule(s: &str) -> Result<Schedule, String> {
    crate::config::parse_schedule(s).map_err(|e| format!("--schedule: {e}"))
}

/// Architecture parsing for `--arch`: the error enumerates every
/// preset (MoE variants included).
pub fn parse_arch(s: &str) -> Result<TransformerArch, String> {
    crate::config::parse_arch(s).map_err(|e| format!("--arch: {e}"))
}

/// Sync-discipline parsing for `--sync sync|async:S`.
pub fn parse_sync(s: &str) -> Result<SyncMode, String> {
    crate::config::parse_sync(s).map_err(|e| format!("--sync: {e}"))
}

/// Checkpoint-cadence parsing for `--ckpt off|auto|every:S`.
pub fn parse_ckpt(s: &str) -> Result<CkptInterval, String> {
    crate::config::parse_ckpt(s).map_err(|e| format!("--ckpt: {e}"))
}

/// Parse the shared reliability flags — `--ckpt off|auto|every:S`,
/// `--mtbf HOURS` (per-GPU override of the hardware spec's figure),
/// `--elastic` — into a [`Reliability`] spec. Flags left unset keep
/// the unarmed default; `Reliability::validate` (run by the callers'
/// config/study validation) rejects `--mtbf`/`--elastic` without an
/// armed `--ckpt`.
pub fn reliability_from_args(args: &Args) -> Result<Reliability, String> {
    let mut r = Reliability::OFF;
    if let Some(s) = args.get("ckpt") {
        r.ckpt = parse_ckpt(s)?;
    }
    if let Some(s) = args.get("mtbf") {
        let hours = s.parse::<f64>().map_err(|_| {
            format!("--mtbf: '{s}' is not an MTBF in hours")
        })?;
        r.mtbf_hours = Some(hours);
    }
    if args.has("elastic") {
        r.elastic = true;
    }
    Ok(r)
}

/// Parse the shared stochastic flags — `--jitter lognormal:S|pareto:A`,
/// `--seed N` (decimal or `0x` hex), `--seeds K` replicates — into a
/// [`Jitter`] spec. Flags left unset keep the unarmed defaults;
/// `Jitter::validate` (run by the callers' config/study validation)
/// rejects `--seed`/`--seeds` without an armed `--jitter`.
pub fn jitter_from_args(args: &Args) -> Result<Jitter, String> {
    let mut j = Jitter::OFF;
    if let Some(s) = args.get("jitter") {
        j.dist = crate::config::parse_jitter(s)
            .map_err(|e| format!("--jitter: {e}"))?;
    }
    if let Some(s) = args.get("seed") {
        j.seed = parse_seed(s).map_err(|e| format!("--seed: {e}"))?;
    }
    if let Some(s) = args.get("seeds") {
        j.replicates = s.parse::<u32>().map_err(|_| {
            format!("--seeds: '{s}' is not a replicate count")
        })?;
    }
    Ok(j)
}

/// Parse a `--seed` value: decimal or `0x`-prefixed hex u64. Shared by
/// the grid flags above and the scenario seed override (CLI + serve).
pub fn parse_seed(s: &str) -> Result<u64, String> {
    let parsed = if let Some(hex) =
        s.strip_prefix("0x").or_else(|| s.strip_prefix("0X"))
    {
        u64::from_str_radix(hex, 16)
    } else {
        s.parse::<u64>()
    };
    parsed
        .map_err(|_| format!("'{s}' is not a u64 seed (decimal or 0x hex)"))
}

/// Parse a "tp2pp4cp1"-style plan shape (missing degrees default to 1).
pub fn parse_plan_shape(s: &str) -> Option<(usize, usize, usize)> {
    if s.is_empty() {
        return None;
    }
    let (mut tp, mut pp, mut cp) = (1usize, 1usize, 1usize);
    let mut rest = s;
    while !rest.is_empty() {
        let (target, tail) = if let Some(t) = rest.strip_prefix("tp") {
            (&mut tp, t)
        } else if let Some(t) = rest.strip_prefix("pp") {
            (&mut pp, t)
        } else if let Some(t) = rest.strip_prefix("cp") {
            (&mut cp, t)
        } else {
            return None;
        };
        let end = tail
            .char_indices()
            .find(|(_, c)| !c.is_ascii_digit())
            .map(|(i, _)| i)
            .unwrap_or(tail.len());
        *target = tail[..end].parse().ok()?;
        rest = &tail[end..];
    }
    Some((tp, pp, cp))
}

/// Build one `SimConfig` from `simulate`-style flags (`--arch`,
/// `--gen`, `--nodes`/`--gpus`, plan degrees, batch shape, sharding,
/// schedule), or load it whole from `--config run.toml`.
pub fn sim_config_from_args(args: &Args) -> Result<SimConfig, String> {
    if let Some(path) = args.get("config") {
        if path.ends_with(".toml") {
            return RunConfig::from_toml_file(path).map(|rc| rc.sim());
        }
    }
    let arch = parse_arch(&args.get_or("arch", "7b"))?;
    let gen = parse_hw(&args.get_or("gen", "h100"))?;
    let cluster = if args.has("gpus") {
        if args.has("nodes") {
            return Err("give --nodes or --gpus, not both".into());
        }
        Cluster::with_gpus(gen, args.usize_or("gpus", 0))
            .map_err(|e| format!("--gpus: {e}"))?
    } else {
        Cluster::new(gen, args.usize_or("nodes", 32))
    };
    let tp = args.usize_or("tp", 1);
    let pp = args.usize_or("pp", 1);
    let cp = args.usize_or("cp", 1);
    let mp = tp * pp * cp;
    if cluster.world_size() % mp != 0 {
        return Err(format!(
            "tp*pp*cp={} must divide world={}",
            mp,
            cluster.world_size()
        ));
    }
    let plan = ParallelPlan::new(cluster.world_size() / mp, tp, pp, cp)
        .with_ep(args.usize_or("ep", 1));
    let mut cfg = SimConfig::fsdp(
        arch,
        cluster,
        plan,
        args.usize_or("gbs", 2 * plan.dp),
        args.usize_or("mbs", 2),
        args.usize_or("seq", 4096),
    );
    if let Some(s) = args.get("sharding") {
        cfg.sharding = parse_sharding(s)?;
        if args.has("ddp") && cfg.sharding != Sharding::Ddp {
            return Err(format!(
                "--ddp conflicts with --sharding {}; drop one",
                cfg.sharding
            ));
        }
    } else if args.has("ddp") {
        cfg.sharding = Sharding::Ddp;
    }
    if let Some(s) = args.get("schedule") {
        cfg.schedule = parse_schedule(s)?;
    }
    if let Some(s) = args.get("sync") {
        cfg.sync = parse_sync(s)?;
    }
    cfg.jitter = jitter_from_args(args)?;
    cfg.relia = reliability_from_args(args)?;
    cfg.validate()?;
    Ok(cfg)
}

/// Build a Study from `--grid` axis flags.
pub fn study_from_args(args: &Args) -> Result<Study, String> {
    let list = |key: &str, default: &str| -> Vec<String> {
        args.get_or(key, default)
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect()
    };
    let usizes = |key: &str, default: &str| -> Result<Vec<usize>, String> {
        list(key, default)
            .iter()
            .map(|s| {
                s.parse::<usize>()
                    .map_err(|_| format!("--{key}: '{s}' is not an integer"))
            })
            .collect()
    };

    let mut archs = Vec::new();
    for name in list("arch", "7b") {
        archs.push(parse_arch(&name)?);
    }
    let mut gens = Vec::new();
    for name in list("gen", "h100") {
        gens.push(parse_hw(&name)?);
    }
    if gens.is_empty() {
        return Err("--gen names no hardware".into());
    }
    let mut shardings = Vec::new();
    for name in list("sharding", "fsdp") {
        shardings.push(parse_sharding(&name)?);
    }
    let mut schedules = Vec::new();
    for name in list("schedule", "1f1b") {
        schedules.push(parse_schedule(&name)?);
    }
    let mut syncs = Vec::new();
    for name in list("sync", "sync") {
        syncs.push(parse_sync(&name)?);
    }

    let plans = match args.get_or("plans", "sweep").as_str() {
        "sweep" => PlanAxis::Sweep { with_cp: false },
        "sweep-cp" => PlanAxis::Sweep { with_cp: true },
        "dp" => PlanAxis::DataParallel,
        spec => PlanAxis::Shapes(
            spec.split(',')
                .map(str::trim)
                .filter(|s| !s.is_empty())
                .map(|s| {
                    parse_plan_shape(s).ok_or_else(|| {
                        format!(
                            "--plans: '{s}' is not sweep|sweep-cp|dp or a \
                             tpXppYcpZ shape (expert parallelism is the \
                             --ep axis, e.g. --ep 1,2,8)"
                        )
                    })
                })
                .collect::<Result<Vec<_>, String>>()?,
        ),
    };

    // Cluster sizes: --nodes, or --gpus (each count must be a multiple
    // of the hardware's NVLink-domain size; the error reports the
    // offending axis value instead of aborting).
    let nodes = if args.has("gpus") {
        if args.has("nodes") {
            return Err("give --nodes or --gpus, not both".into());
        }
        let domains: std::collections::BTreeSet<usize> =
            gens.iter().map(|hw| hw.spec().gpus_per_node).collect();
        if domains.len() > 1 {
            return Err(format!(
                "--gpus needs one NVLink-domain size, but --gen mixes \
                 {domains:?}; use --nodes instead"
            ));
        }
        let mut nodes = Vec::new();
        for gpus in usizes("gpus", "256")? {
            nodes.push(
                Cluster::with_gpus(gens[0], gpus)
                    .map_err(|e| format!("--gpus: {e}"))?
                    .nodes,
            );
        }
        nodes
    } else {
        usizes("nodes", "32")?
    };

    let mut b = Study::builder(&args.get_or("name", "grid"))
        .title("ad-hoc study grid")
        .archs(archs)
        .hardware(gens)
        .nodes(nodes)
        .plans(plans)
        .seq_lens(usizes("seq", "4096")?)
        .shardings(shardings)
        .schedules(schedules)
        .eps(usizes("ep", "1")?)
        .sync_modes(syncs);

    b = if args.has("lbs") {
        b.batch_per_replica(args.usize_or("lbs", 2))
    } else {
        b.global_batches(usizes("gbs", "512")?)
    };
    b = match args.get_or("mbs", "divisors").as_str() {
        "divisors" => b.micro_batch_divisors(),
        _ => b.micro_batches(usizes("mbs", "2")?),
    };
    let cap = args.f64_or("cap", 0.94);
    if cap > 0.0 {
        b = b.memory_cap(cap);
    }
    let jitter = jitter_from_args(args)?;
    b = b.jitter(jitter.dist).seed(jitter.seed).seeds(jitter.replicates);
    let relia = reliability_from_args(args)?;
    b = b.checkpoint(relia.ckpt).elastic(relia.elastic);
    if let Some(hours) = relia.mtbf_hours {
        b = b.mtbf_override(hours);
    }
    b.try_build()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn grid_errors_are_plain_strings_with_flag_prefixes() {
        let err = parse_hw("h900").unwrap_err();
        assert!(err.starts_with("--gen: "), "{err}");
        let err = parse_sharding("zero2").unwrap_err();
        assert!(err.starts_with("--sharding: "), "{err}");
        assert!(err.contains("fsdp, ddp, hsdp:G, zero3"), "{err}");
        let err = parse_schedule("gpipe").unwrap_err();
        assert!(err.starts_with("--schedule: "), "{err}");
    }

    #[test]
    fn sim_config_defaults_match_the_cli() {
        let cfg = sim_config_from_args(&parse("simulate")).unwrap();
        assert_eq!(cfg.arch.name, "llama-7b");
        assert_eq!(cfg.cluster.nodes, 32);
        assert_eq!(cfg.seq_len, 4096);
    }

    #[test]
    fn jitter_flags_arm_configs_and_grids() {
        // Simulate-style: --jitter + --seed lands on the SimConfig.
        let cfg = sim_config_from_args(&parse(
            "simulate --nodes 2 --jitter lognormal:0.2 --seed 0xBEEF",
        ))
        .unwrap();
        assert_eq!(
            cfg.jitter.dist,
            crate::sim::JitterDist::Lognormal { sigma: 0.2 }
        );
        assert_eq!(cfg.jitter.seed, 0xBEEF);

        // Study-style: --seeds fans every grid point into replicates.
        let study = study_from_args(&parse(
            "study --grid --nodes 2 --gbs 48 --jitter pareto:2.5 \
             --seed 7 --seeds 8",
        ))
        .unwrap();
        assert_eq!(study.jitter().seed, 7);
        assert_eq!(study.jitter().replicates, 8);
        assert!(study
            .expand()
            .iter()
            .all(|p| p.cfg.jitter == study.jitter()));

        // --seed without --jitter is the documented arming error, on
        // both paths.
        let err = sim_config_from_args(&parse("simulate --seed 7"))
            .unwrap_err();
        assert!(err.contains("jitter=off"), "{err}");
        let err =
            study_from_args(&parse("study --grid --nodes 2 --seeds 4"))
                .unwrap_err();
        assert!(err.contains("jitter=off"), "{err}");

        // Malformed values name the flag.
        let err = sim_config_from_args(&parse(
            "simulate --jitter gauss:1",
        ))
        .unwrap_err();
        assert!(err.starts_with("--jitter: "), "{err}");
        let err = sim_config_from_args(&parse(
            "simulate --jitter lognormal:0.2 --seed banana",
        ))
        .unwrap_err();
        assert!(err.starts_with("--seed: "), "{err}");
    }

    #[test]
    fn moe_and_sync_flags_reach_configs_and_grids() {
        // Simulate-style: --arch MoE preset + --ep + --sync.
        let cfg = sim_config_from_args(&parse(
            "simulate --arch 7b-moe8x --nodes 1 --ep 8 --sync async:4 \
             --gbs 16 --mbs 2",
        ))
        .unwrap();
        assert!(cfg.arch.is_moe());
        assert_eq!(cfg.plan.ep, 8);
        assert_eq!(cfg.sync, SyncMode::Async { max_staleness: 4 });

        // Study-style: the same flags become axes.
        let study = study_from_args(&parse(
            "study --grid --arch 7b-moe8x --nodes 1 --gbs 16 \
             --plans dp --mbs 2 --ep 1,8 --sync sync,async:4",
        ))
        .unwrap();
        assert!(study.has_async());
        let pts = study.expand();
        assert_eq!(pts.len(), 4);
        assert!(pts.iter().any(
            |p| p.cfg.plan.ep == 8 && !p.cfg.sync.is_sync()));

        // Errors name the flag and enumerate accepted forms.
        let err = sim_config_from_args(&parse(
            "simulate --arch gpt-9000",
        ))
        .unwrap_err();
        assert!(err.starts_with("--arch: "), "{err}");
        assert!(err.contains("7b-moe8x"), "{err}");
        let err = sim_config_from_args(&parse(
            "simulate --sync bsp",
        ))
        .unwrap_err();
        assert!(err.starts_with("--sync: "), "{err}");
        assert!(err.contains("sync, async:S"), "{err}");
        // ep on a dense arch is a validation error, not a silent noop.
        let err = sim_config_from_args(&parse(
            "simulate --nodes 1 --ep 8 --gbs 16 --mbs 2",
        ))
        .unwrap_err();
        assert!(err.contains("mixture-of-experts"), "{err}");
    }

    #[test]
    fn reliability_flags_arm_configs_and_grids() {
        // Simulate-style: --ckpt + --mtbf land on the SimConfig.
        let cfg = sim_config_from_args(&parse(
            "simulate --nodes 2 --ckpt every:1800 --mtbf 30000",
        ))
        .unwrap();
        assert_eq!(cfg.relia.ckpt,
                   CkptInterval::Every { seconds: 1800.0 });
        assert_eq!(cfg.relia.mtbf_hours, Some(30000.0));

        // Study-style: the same flags arm every grid point; --elastic
        // rides on an all-async sync axis.
        let study = study_from_args(&parse(
            "study --grid --nodes 2 --gbs 48 --ckpt auto --elastic \
             --sync async:4",
        ))
        .unwrap();
        assert!(study.has_reliability());
        assert!(study
            .expand()
            .iter()
            .all(|p| p.cfg.relia == study.reliability()));

        // --mtbf/--elastic without --ckpt is the documented arming
        // error, on both paths.
        let err = sim_config_from_args(&parse("simulate --mtbf 100"))
            .unwrap_err();
        assert!(err.contains("arm --ckpt"), "{err}");
        let err = study_from_args(&parse(
            "study --grid --nodes 2 --elastic --sync async:4",
        ))
        .unwrap_err();
        assert!(err.contains("arm --ckpt"), "{err}");
        // Elastic without async is rejected too.
        let err = sim_config_from_args(&parse(
            "simulate --nodes 2 --ckpt auto --elastic",
        ))
        .unwrap_err();
        assert!(err.contains("--sync async"), "{err}");

        // Malformed values name the flag and enumerate accepted forms.
        let err = sim_config_from_args(&parse(
            "simulate --ckpt hourly",
        ))
        .unwrap_err();
        assert!(err.starts_with("--ckpt: "), "{err}");
        assert!(err.contains("off, auto, every:S"), "{err}");
        let err = sim_config_from_args(&parse(
            "simulate --ckpt auto --mtbf often",
        ))
        .unwrap_err();
        assert!(err.starts_with("--mtbf: "), "{err}");
    }

    #[test]
    fn study_from_request_style_pairs() {
        // Serve-mode requests build Args from pairs, not a command line;
        // the same grid must come out.
        let from_cli = study_from_args(&parse(
            "study --grid --nodes 2 --gbs 48 --plans sweep",
        ))
        .unwrap();
        let from_pairs = study_from_args(&Args::from_pairs(
            vec![],
            [
                ("grid".to_string(), "true".to_string()),
                ("nodes".to_string(), "2".to_string()),
                ("gbs".to_string(), "48".to_string()),
                ("plans".to_string(), "sweep".to_string()),
            ],
        ))
        .unwrap();
        assert_eq!(from_cli.expand().len(), from_pairs.expand().len());
    }
}
