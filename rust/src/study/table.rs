//! Rendered experiment results: the `Table` type every scenario and
//! sink works in terms of, plus the declarative `Column` vocabulary
//! that turns a `CaseResult` row into formatted cells.
//!
//! `Table` moved here from `report` when the Study API became the
//! crate's experiment surface; `report` re-exports it for
//! compatibility. CSV output is byte-identical to the old writer.

use std::path::Path;

use crate::util::csv::CsvWriter;

use super::runner::CaseResult;

/// A rendered experiment result.
#[derive(Debug, Clone, PartialEq)]
pub struct Table {
    pub name: String,
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
    /// Optional column index to visualize as an ASCII bar chart.
    pub chart_col: Option<usize>,
}

impl Table {
    pub fn new(name: &str, title: &str, header: &[&str]) -> Table {
        Table {
            name: name.to_string(),
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            chart_col: None,
        }
    }

    pub fn row(&mut self, fields: Vec<String>) {
        assert_eq!(fields.len(), self.header.len(),
                   "row width mismatch in {}", self.name);
        self.rows.push(fields);
    }

    pub fn with_chart(mut self, col: usize) -> Table {
        self.chart_col = Some(col);
        self
    }

    /// Write `<out_dir>/<name>.csv`.
    pub fn write_csv(&self, out_dir: &Path) -> std::io::Result<()> {
        let header: Vec<&str> =
            self.header.iter().map(|s| s.as_str()).collect();
        let mut w = CsvWriter::create(
            out_dir.join(format!("{}.csv", self.name)), &header)?;
        for r in &self.rows {
            w.row(r)?;
        }
        w.finish()?;
        Ok(())
    }

    /// Render the table as a CSV string — byte-identical to the file
    /// [`Table::write_csv`] produces. Serve mode ships this string in
    /// its `table` events so cold and warm answers can be compared
    /// byte-for-byte.
    pub fn csv_string(&self) -> String {
        let mut out = String::new();
        out.push_str(&self.header.join(","));
        out.push('\n');
        for r in &self.rows {
            let escaped: Vec<String> =
                r.iter().map(|f| crate::util::csv::escape(f)).collect();
            out.push_str(&escaped.join(","));
            out.push('\n');
        }
        out
    }

    /// Print an aligned text table (+ optional bar chart).
    pub fn print(&self) {
        println!("\n── {} ─ {}", self.name, self.title);
        let mut widths: Vec<usize> =
            self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, f) in r.iter().enumerate() {
                widths[i] = widths[i].max(f.len());
            }
        }
        let fmt_row = |r: &[String]| {
            r.iter()
                .enumerate()
                .map(|(i, f)| format!("{:>w$}", f, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        println!("{}", fmt_row(&self.header));
        for r in &self.rows {
            println!("{}", fmt_row(r));
        }
        if let Some(col) = self.chart_col {
            let vals: Vec<f64> = self
                .rows
                .iter()
                .filter_map(|r| r[col].parse::<f64>().ok())
                .collect();
            if !vals.is_empty() {
                let max = vals.iter().cloned().fold(f64::MIN, f64::max);
                println!("\n  {} (bar chart)", self.header[col]);
                for (r, v) in self.rows.iter().zip(&vals) {
                    let bars =
                        ((v / max) * 48.0).round().max(0.0) as usize;
                    println!(
                        "  {:>12} | {}{}",
                        r[0],
                        "█".repeat(bars),
                        format_args!(" {:.4}", v)
                    );
                }
            }
        }
    }
}

// Shared numeric formatters (the figure harness's house style).
pub fn f0(x: f64) -> String { format!("{x:.0}") }
pub fn f2(x: f64) -> String { format!("{x:.2}") }
pub fn f3(x: f64) -> String { format!("{x:.3}") }
/// Seconds rendered as milliseconds with one decimal.
pub fn ms(x: f64) -> String { format!("{:.1}", x * 1e3) }

/// One declaratively-rendered column of a study result table.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Column {
    Arch,
    /// Hardware name under the historical `gen` CSV header (the paper
    /// figures' schema; byte-stable across the catalog migration).
    Gen,
    /// Hardware name under a `hardware` header — for catalog-centric
    /// scenarios where "generation" would be a misnomer.
    Hardware,
    Nodes,
    Gpus,
    Plan,
    ShardingKind,
    ScheduleKind,
    Mbs,
    Gbs,
    SeqLen,
    GlobalWps,
    PerGpuWps,
    Mfu,
    ExposedMs,
    CommMs,
    ComputeMs,
    PowerW,
    TotalPowerKw,
    WpsPerWatt,
    EnergyPerTokenJ,
    MemGb,
    /// Median iteration time over a point's seeded replicates, ms.
    IterP50Ms,
    /// 95th-percentile iteration time over the seeded replicates, ms.
    IterP95Ms,
    /// 99th-percentile iteration time over the seeded replicates, ms.
    IterP99Ms,
    /// Tail-aware throughput: tokens / p95 iteration time.
    P95Wps,
    /// Gradient-sync discipline spec string ("sync", "async:S").
    SyncModeKind,
    /// Staleness-discounted effective throughput:
    /// `global_wps / sync.staleness_discount()` — equals `global_wps`
    /// bit for bit under [`crate::sim::SyncMode::Sync`]
    /// (`docs/moe.md` §Staleness).
    EffectiveWps,
    /// Reliability-axis spec string ("auto", "every:1800",
    /// "auto+elastic", ...) under a `ckpt` header.
    CkptKind,
    /// Failure-aware goodput: `global_wps × availability` under the
    /// case's checkpoint cadence and hardware reliability figures —
    /// equals `global_wps` bit for bit when the axis is off
    /// (`docs/reliability.md`).
    GoodputWps,
}

impl Column {
    pub fn header(self) -> &'static str {
        match self {
            Column::Arch => "arch",
            Column::Gen => "gen",
            Column::Hardware => "hardware",
            Column::Nodes => "nodes",
            Column::Gpus => "gpus",
            Column::Plan => "plan",
            Column::ShardingKind => "sharding",
            Column::ScheduleKind => "schedule",
            Column::Mbs => "mbs",
            Column::Gbs => "gbs",
            Column::SeqLen => "seq_len",
            Column::GlobalWps => "global_wps",
            Column::PerGpuWps => "wps_per_gpu",
            Column::Mfu => "mfu",
            Column::ExposedMs => "exposed_ms",
            Column::CommMs => "comm_ms",
            Column::ComputeMs => "compute_ms",
            Column::PowerW => "power_w",
            Column::TotalPowerKw => "total_power_kw",
            Column::WpsPerWatt => "wps_per_watt",
            Column::EnergyPerTokenJ => "j_per_token",
            Column::MemGb => "mem_gb",
            Column::IterP50Ms => "p50_ms",
            Column::IterP95Ms => "p95_ms",
            Column::IterP99Ms => "p99_ms",
            Column::P95Wps => "p95_wps",
            Column::SyncModeKind => "sync",
            Column::EffectiveWps => "effective_wps",
            Column::CkptKind => "ckpt",
            Column::GoodputWps => "goodput_wps",
        }
    }

    pub fn cell(self, c: &CaseResult) -> String {
        let m = &c.metrics;
        match self {
            Column::Arch => c.arch.to_string(),
            Column::Gen | Column::Hardware => c.hw.to_string(),
            Column::Nodes => c.nodes.to_string(),
            Column::Gpus => m.world.to_string(),
            Column::Plan => c.plan.to_string(),
            Column::ShardingKind => c.sharding.to_string(),
            Column::ScheduleKind => c.schedule.to_string(),
            Column::Mbs => c.micro_batch.to_string(),
            Column::Gbs => c.global_batch.to_string(),
            Column::SeqLen => c.seq_len.to_string(),
            Column::GlobalWps => f0(m.global_wps),
            Column::PerGpuWps => f0(m.per_gpu_wps),
            Column::Mfu => f3(m.mfu),
            Column::ExposedMs => ms(m.exposed_comm),
            Column::CommMs => ms(m.comm_time),
            Column::ComputeMs => ms(m.compute_time),
            Column::PowerW => f0(m.power_w),
            Column::TotalPowerKw => f2(m.total_power_w / 1e3),
            Column::WpsPerWatt => f2(m.wps_per_watt),
            Column::EnergyPerTokenJ => f2(m.energy_per_token_j),
            Column::MemGb => f2(c.mem_per_gpu / 1e9),
            Column::IterP50Ms => ms(c.iter_p50),
            Column::IterP95Ms => ms(c.iter_p95),
            Column::IterP99Ms => ms(c.iter_p99),
            Column::P95Wps => {
                f0(super::runner::Objective::P95Wps.score(c))
            }
            Column::SyncModeKind => c.sync.to_string(),
            Column::EffectiveWps => {
                f0(m.global_wps / c.sync.staleness_discount())
            }
            Column::CkptKind => c.relia.to_string(),
            Column::GoodputWps => f0(c.goodput_wps()),
        }
    }
}

/// The ad-hoc `--grid` table layout, shared by `dtsim study --grid`
/// and serve mode's `study-grid` so both render byte-identical CSV for
/// the same flags. An unarmed, fully-synchronous grid keeps the
/// historical column set untouched (golden-figure byte stability); a
/// seeded grid appends the iteration-time percentile columns, a grid
/// with any async point appends the sync-mode and
/// staleness-discounted effective-throughput columns after those, and
/// a grid with an armed reliability axis appends the checkpoint-spec
/// and goodput columns last — always extending, never reordering.
pub fn grid_columns(
    jittered: bool, asynced: bool, reliable: bool,
) -> Vec<Column> {
    let mut cols = vec![
        Column::Arch,
        Column::Gen,
        Column::Nodes,
        Column::Plan,
        Column::ShardingKind,
        Column::ScheduleKind,
        Column::Mbs,
        Column::Gbs,
        Column::SeqLen,
        Column::GlobalWps,
        Column::PerGpuWps,
        Column::Mfu,
        Column::ExposedMs,
        Column::WpsPerWatt,
        Column::MemGb,
    ];
    if jittered {
        cols.extend([
            Column::IterP50Ms,
            Column::IterP95Ms,
            Column::IterP99Ms,
        ]);
    }
    if asynced {
        cols.extend([Column::SyncModeKind, Column::EffectiveWps]);
    }
    if reliable {
        cols.extend([Column::CkptKind, Column::GoodputWps]);
    }
    cols
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::Sharding;

    #[test]
    fn column_headers_are_stable() {
        assert_eq!(Column::GlobalWps.header(), "global_wps");
        assert_eq!(Column::PerGpuWps.header(), "wps_per_gpu");
        assert_eq!(Column::MemGb.header(), "mem_gb");
        // The historical figure schema keeps "gen"; catalog-centric
        // scenarios get "hardware" for the same cell.
        assert_eq!(Column::Gen.header(), "gen");
        assert_eq!(Column::Hardware.header(), "hardware");
    }

    #[test]
    fn grid_columns_append_percentiles_only_when_armed() {
        let off = grid_columns(false, false, false);
        let on = grid_columns(true, false, false);
        assert_eq!(&on[..off.len()], &off[..],
                   "armed grids must extend, never reorder, the layout");
        assert_eq!(&on[off.len()..],
                   &[Column::IterP50Ms, Column::IterP95Ms,
                     Column::IterP99Ms]);
        assert_eq!(Column::IterP95Ms.header(), "p95_ms");
        assert_eq!(Column::P95Wps.header(), "p95_wps");
    }

    #[test]
    fn grid_columns_append_sync_columns_only_when_asynced() {
        let off = grid_columns(false, false, false);
        let sync_only = grid_columns(true, true, false);
        assert_eq!(&sync_only[..off.len()], &off[..],
                   "async grids must extend, never reorder, the layout");
        assert_eq!(&sync_only[sync_only.len() - 2..],
                   &[Column::SyncModeKind, Column::EffectiveWps]);
        let async_unjittered = grid_columns(false, true, false);
        assert_eq!(&async_unjittered[..off.len()], &off[..]);
        assert_eq!(&async_unjittered[off.len()..],
                   &[Column::SyncModeKind, Column::EffectiveWps]);
        assert_eq!(Column::SyncModeKind.header(), "sync");
        assert_eq!(Column::EffectiveWps.header(), "effective_wps");
    }

    #[test]
    fn grid_columns_append_reliability_columns_last() {
        // The reliability pair rides after every other optional group,
        // whatever combination is armed — extending, never reordering.
        for (jittered, asynced) in
            [(false, false), (true, false), (false, true), (true, true)]
        {
            let base = grid_columns(jittered, asynced, false);
            let on = grid_columns(jittered, asynced, true);
            assert_eq!(&on[..base.len()], &base[..]);
            assert_eq!(&on[base.len()..],
                       &[Column::CkptKind, Column::GoodputWps]);
        }
        assert_eq!(Column::CkptKind.header(), "ckpt");
        assert_eq!(Column::GoodputWps.header(), "goodput_wps");
    }

    #[test]
    fn sharding_labels() {
        assert_eq!(Sharding::Fsdp.to_string(), "fsdp");
        assert_eq!(Sharding::Ddp.to_string(), "ddp");
        assert_eq!(Sharding::Hsdp { group: 8 }.to_string(), "hsdp:8");
        assert_eq!(Sharding::Zero3.to_string(), "zero3");
    }

    #[test]
    fn schedule_column_renders_spec_strings() {
        use crate::sim::Schedule;
        assert_eq!(Column::ScheduleKind.header(), "schedule");
        assert_eq!(Schedule::Interleaved { v: 2 }.to_string(),
                   "interleaved:2");
    }

    #[test]
    fn csv_string_matches_write_csv_bytes() {
        let mut t = Table::new("csv_parity", "parity", &["a", "b"]);
        t.row(vec!["1".into(), "x,y".into()]);
        t.row(vec!["2".into(), "q\"z".into()]);
        let dir = std::env::temp_dir().join("dtsim_table_csv_parity");
        std::fs::create_dir_all(&dir).unwrap();
        t.write_csv(&dir).unwrap();
        let file_bytes =
            std::fs::read_to_string(dir.join("csv_parity.csv")).unwrap();
        assert_eq!(t.csv_string(), file_bytes);
        assert_eq!(t.csv_string(), "a,b\n1,\"x,y\"\n2,\"q\"\"z\"\n");
    }

    #[test]
    fn formatters_match_house_style() {
        assert_eq!(f0(123.6), "124");
        assert_eq!(f2(1.005), "1.00");
        assert_eq!(f3(0.12345), "0.123");
        assert_eq!(ms(0.0123), "12.3");
    }
}
