//! The Study experiment API: declarative sweep definitions, parallel
//! execution, and a pluggable scenario registry.
//!
//! The paper's core argument (§4.3/§5) is that the optimal
//! parallelization strategy must be *searched*, not assumed. This
//! module makes that search a first-class object:
//!
//! * [`Study`] / [`StudyBuilder`] — declare a grid over architecture ×
//!   hardware (any catalog entry — built-in generation or loaded spec,
//!   via [`.hardware(...)`](StudyBuilder::hardware)) × cluster size ×
//!   parallel plan × sharding × pipeline schedule × batch shape ×
//!   sequence length, with feasibility constraints (divisibility,
//!   schedule validity, per-spec device-memory cap) applied during
//!   expansion.
//! * [`StudyRunner`] — expands the grid, deduplicates repeated
//!   configurations via a config-key cache, and simulates the remainder
//!   across `std::thread::scope` workers (the simulator is
//!   embarrassingly parallel). Results come back in deterministic grid
//!   order regardless of thread count.
//! * [`Scenario`] + [`Registry`] — a named experiment (each paper
//!   figure, or a user-defined study) that renders one or more
//!   [`Table`]s; `dtsim study <name>` and `dtsim repro` both dispatch
//!   through the registry.
//! * [`Sink`] — one interface for emitting tables to the console, CSV,
//!   or JSON.
//!
//! A figure definition reads like this (see `report::figures` for the
//! full set):
//!
//! ```ignore
//! let study = Study::builder("fig6")
//!     .title("Model parallelism increases FSDP throughput")
//!     .arch(LLAMA_7B)
//!     .hardware([HwId::H100])
//!     .nodes([32])
//!     .plans(PlanAxis::Sweep { with_cp: false })
//!     .global_batches([512])
//!     .micro_batch_divisors()
//!     .memory_cap(0.94)
//!     .build();
//! let mut result = runner.run(&study);
//! result.sort_by_wps();
//! let table = result.table(&[Column::Plan, Column::Mbs, Column::GlobalWps]);
//! ```

pub mod grid;
pub mod runner;
pub mod scenario;
pub mod sink;
pub mod table;

pub use runner::{
    Cancelled, CaseResult, Objective, StudyResult, StudyRunner,
};
pub use scenario::{Registry, Scenario, ScenarioOpts};
pub use sink::{ConsoleSink, CsvSink, JsonSink, Sink};
pub use table::{grid_columns, Column, Table};

use crate::hardware::HwId;
use crate::memory;
use crate::model::TransformerArch;
use crate::parallelism::{enumerate_plans, ParallelPlan};
use crate::sim::{CkptInterval, Jitter, JitterDist, Reliability, Schedule,
                 Sharding, SimConfig, SyncMode};
use crate::topology::Cluster;

/// How the parallel-plan axis expands for each (generation, nodes)
/// cluster in the grid.
#[derive(Debug, Clone)]
pub enum PlanAxis {
    /// Pure FSDP: dp = world size.
    DataParallel,
    /// The paper's §3 sweep over tp/pp degrees {1,2,4,8,16} (and
    /// optionally cp) that fill the cluster.
    Sweep { with_cp: bool },
    /// Explicit plans; ones not matching the cluster world are skipped.
    Fixed(Vec<ParallelPlan>),
    /// (tp, pp, cp) shapes with dp derived from the world size.
    Shapes(Vec<(usize, usize, usize)>),
}

impl PlanAxis {
    fn expand(&self, cluster: &Cluster, n_layers: usize) -> Vec<ParallelPlan> {
        let world = cluster.world_size();
        match self {
            PlanAxis::DataParallel => {
                vec![ParallelPlan::data_parallel(world)]
            }
            PlanAxis::Sweep { with_cp } => {
                enumerate_plans(cluster, n_layers, *with_cp)
            }
            PlanAxis::Fixed(plans) => plans
                .iter()
                .copied()
                .filter(|p| p.world_size() == world)
                .collect(),
            PlanAxis::Shapes(shapes) => shapes
                .iter()
                .filter_map(|&(tp, pp, cp)| {
                    let mp = tp * pp * cp;
                    if mp == 0 || world % mp != 0 {
                        return None;
                    }
                    Some(ParallelPlan::new(world / mp, tp, pp, cp))
                })
                .collect(),
        }
    }
}

/// How the global batch is derived for each plan.
#[derive(Debug, Clone)]
pub enum BatchAxis {
    /// Explicit global batch sizes (strong scaling).
    Fixed(Vec<usize>),
    /// gbs = factor × dp — a fixed per-replica batch (weak scaling).
    PerReplica(usize),
}

/// Which microbatch sizes to try for a per-replica batch.
#[derive(Debug, Clone)]
pub enum MicroBatchAxis {
    Fixed(Vec<usize>),
    /// Every divisor of the per-replica batch gbs/dp — no batch shape
    /// is silently skipped (gbs 48 at dp 16 tries mbs 1 and 3).
    Divisors,
}

/// All divisors of `n` in ascending order (empty for n = 0).
pub fn divisors(n: usize) -> Vec<usize> {
    let mut small = Vec::new();
    let mut large = Vec::new();
    let mut d = 1;
    while d.saturating_mul(d) <= n {
        if n % d == 0 {
            small.push(d);
            if d != n / d {
                large.push(n / d);
            }
        }
        d += 1;
    }
    large.reverse();
    small.extend(large);
    small
}

/// The pinned benchmark grid shared by `benches/study_runner.rs`, the
/// `dtsim bench` smoke mode, and CI's `BENCH_study.json`: the Fig. 6
/// parallelization sweep (Llama-7B, 256 H100 GPUs, gbs 512, divisor
/// microbatches, 0.94 memory cap). Pinned so configs/s is comparable
/// across PRs.
pub fn bench_pinned_study() -> Study {
    Study::builder("bench-fig6")
        .title("pinned benchmark grid: fig6 parallelization sweep")
        .arch(crate::model::LLAMA_7B)
        .generation(HwId::H100)
        .nodes([32])
        .plans(PlanAxis::Sweep { with_cp: false })
        .global_batches([512])
        .micro_batch_divisors()
        .memory_cap(0.94)
        .build()
}

/// Pinned companion grid covering the schedule axis (interleaved-1F1B
/// × ZeRO-3 on pipeline-heavy plans), so `dtsim bench` and CI's
/// `BENCH_study.json` track the schedule-variant hot path alongside
/// the fig6 sweep. Pinned for cross-PR comparability.
pub fn bench_pinned_sched_study() -> Study {
    Study::builder("bench-sched")
        .title("pinned benchmark grid: schedule variants (interleaved/zero3)")
        .arch(crate::model::LLAMA_7B)
        .generation(HwId::H100)
        .nodes([16])
        .plans(PlanAxis::Shapes(vec![(1, 4, 1), (2, 4, 1), (1, 8, 1)]))
        .global_batches([256])
        .micro_batches([1, 2])
        .schedules([
            Schedule::OneFOneB,
            Schedule::Interleaved { v: 2 },
            Schedule::Interleaved { v: 4 },
        ])
        .shardings([Sharding::Fsdp, Sharding::Zero3])
        .memory_cap(0.94)
        .build()
}

/// Pinned companion grid covering the hardware axis (every catalog
/// built-in, GB200's 72-GPU NVLink domain included), so `dtsim bench`
/// and CI's `BENCH_study.json` catch cost-cache regressions from the
/// interned `HwId` key migration. Pinned for cross-PR comparability.
pub fn bench_pinned_hw_study() -> Study {
    Study::builder("bench-hw")
        .title("pinned benchmark grid: hardware axis (catalog built-ins)")
        .arch(crate::model::LLAMA_7B)
        .hardware(HwId::ALL)
        .nodes([2])
        .plan_shapes(&[(1, 1, 1), (2, 1, 1), (2, 2, 1)])
        .batch_per_replica(2)
        .micro_batches([1, 2])
        .memory_cap(0.94)
        .build()
}

/// Pinned stochastic companion grid: the Fig. 6 core plans under a
/// seeded lognormal straggler distribution with 8 replicates per
/// config, so `dtsim bench` and CI's `BENCH_study.json` track the
/// replicated-evaluation hot path (seeded-grid fields are
/// informational — no baseline gate). Pinned for cross-PR
/// comparability.
pub fn bench_pinned_stochastic_study() -> Study {
    Study::builder("bench-stochastic")
        .title("pinned benchmark grid: seeded straggler replicates")
        .arch(crate::model::LLAMA_7B)
        .generation(HwId::H100)
        .nodes([16])
        .plan_shapes(&[(1, 1, 1), (2, 1, 1), (4, 1, 1), (1, 4, 1)])
        .global_batches([256])
        .micro_batches([1, 2])
        .memory_cap(0.94)
        .jitter(JitterDist::Lognormal { sigma: 0.15 })
        .seed(7)
        .seeds(8)
        .build()
}

/// Pinned sparse/async companion grid: the 7b-moe8x preset swept over
/// expert-parallel degrees and both synchronization disciplines, so
/// `dtsim bench` and CI's `BENCH_study.json` track the MoE AllToAll +
/// staleness-amortization hot path (moe_* fields are informational —
/// no baseline gate). Pinned for cross-PR comparability.
pub fn bench_pinned_moe_study() -> Study {
    Study::builder("bench-moe")
        .title("pinned benchmark grid: MoE expert parallelism + async DP")
        .arch(crate::model::LLAMA_7B_MOE8X)
        .generation(HwId::H100)
        .nodes([4])
        .plan_shapes(&[(1, 1, 1), (2, 1, 1), (1, 4, 1)])
        .eps([1, 2, 4, 8])
        .sync_modes([SyncMode::Sync, SyncMode::Async { max_staleness: 4 }])
        .global_batches([64])
        .micro_batches([1, 2])
        .memory_cap(0.94)
        .build()
}

/// One expanded, validated grid point plus its memory footprint.
#[derive(Debug, Clone, Copy)]
pub struct StudyPoint {
    pub cfg: SimConfig,
    pub mem_per_gpu: f64,
}

/// Cache/dedup key: the complete value identity of a `SimConfig` —
/// the full architecture (not just its name, so a customized arch
/// never aliases a preset's cache entry), the interned hardware id
/// (catalog specs are immutable, so the id *is* the spec's value
/// identity), the cluster shape, and every workload axis.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ConfigKey {
    pub(crate) arch: TransformerArch,
    pub(crate) hw: HwId,
    pub(crate) nodes: usize,
    pub(crate) gpus_per_node: usize,
    pub(crate) plan: ParallelPlan,
    pub(crate) global_batch: usize,
    pub(crate) micro_batch: usize,
    pub(crate) seq_len: usize,
    pub(crate) sharding: Sharding,
    pub(crate) schedule: Schedule,
    pub(crate) prefetch: bool,
    /// The stochastic axis (distribution, base seed, replicate count).
    /// Part of the key so the `ResultStore` dedup cache never conflates
    /// differently-seeded evaluations of the same workload: a seed-7
    /// table answered from a seed-8 run would be silently wrong.
    pub(crate) jitter: Jitter,
    /// The gradient-synchronization discipline. Part of the key so the
    /// store never conflates sync disciplines: an `async:4` table
    /// answered from a synchronous run (or vice versa) would be
    /// silently wrong. Note `plan.ep` rides along inside `plan`.
    pub(crate) sync: SyncMode,
    /// The failure/checkpoint axis. Part of the key so the store never
    /// conflates reliability assumptions: a goodput table under one
    /// checkpoint cadence or MTBF answered from another would be
    /// silently wrong.
    pub(crate) relia: Reliability,
}

impl ConfigKey {
    pub fn of(cfg: &SimConfig) -> ConfigKey {
        ConfigKey {
            arch: cfg.arch,
            hw: cfg.cluster.node.gpu,
            nodes: cfg.cluster.nodes,
            gpus_per_node: cfg.cluster.gpus_per_node(),
            plan: cfg.plan,
            global_batch: cfg.global_batch,
            micro_batch: cfg.micro_batch,
            seq_len: cfg.seq_len,
            sharding: cfg.sharding,
            schedule: cfg.schedule,
            prefetch: cfg.prefetch,
            jitter: cfg.jitter,
            sync: cfg.sync,
            relia: cfg.relia,
        }
    }
}

/// A declarative experiment grid. Build with [`Study::builder`].
#[derive(Debug, Clone)]
pub struct Study {
    pub name: String,
    pub title: String,
    archs: Vec<TransformerArch>,
    hws: Vec<HwId>,
    nodes: Vec<usize>,
    plans: PlanAxis,
    batches: BatchAxis,
    micro: MicroBatchAxis,
    seqs: Vec<usize>,
    shardings: Vec<Sharding>,
    schedules: Vec<Schedule>,
    prefetch: Vec<bool>,
    mem_cap_frac: Option<f64>,
    jitter: Jitter,
    eps: Vec<usize>,
    syncs: Vec<SyncMode>,
    relia: Reliability,
}

impl Study {
    pub fn builder(name: &str) -> StudyBuilder {
        StudyBuilder {
            name: name.to_string(),
            title: String::new(),
            archs: Vec::new(),
            hws: vec![HwId::H100],
            nodes: vec![1],
            plans: PlanAxis::DataParallel,
            batches: BatchAxis::PerReplica(2),
            micro: MicroBatchAxis::Fixed(vec![2]),
            seqs: vec![4096],
            shardings: vec![Sharding::Fsdp],
            schedules: vec![Schedule::OneFOneB],
            prefetch: vec![true],
            mem_cap_frac: None,
            jitter: Jitter::OFF,
            eps: vec![1],
            syncs: vec![SyncMode::Sync],
            relia: Reliability::OFF,
        }
    }

    /// The study's stochastic axis ([`Jitter::OFF`] unless armed via
    /// [`StudyBuilder::jitter`]).
    pub fn jitter(&self) -> Jitter {
        self.jitter
    }

    /// True when any point on the sync axis is staleness-tolerant —
    /// drives the `sync` / `effective_wps` grid columns, mirroring how
    /// the armed jitter axis drives the percentile columns.
    pub fn has_async(&self) -> bool {
        self.syncs.iter().any(|s| !s.is_sync())
    }

    /// The study's failure/checkpoint axis ([`Reliability::OFF`]
    /// unless armed via [`StudyBuilder::checkpoint`]).
    pub fn reliability(&self) -> Reliability {
        self.relia
    }

    /// True when the reliability axis is armed — drives the `ckpt` /
    /// `goodput_wps` grid columns, mirroring how armed jitter drives
    /// the percentile columns and async drives the sync columns.
    pub fn has_reliability(&self) -> bool {
        !self.relia.is_off()
    }

    /// Expand the grid into validated, memory-feasible simulation
    /// configurations. Expansion order is deterministic: axes nest
    /// arch → generation → nodes → seq → sharding → schedule →
    /// prefetch → plan → ep → gbs → mbs → sync, with plans in
    /// `enumerate_plans` order and microbatch candidates ascending —
    /// the same candidate order the planner's sweep has always used,
    /// so stable sorts preserve its tie-breaks (ep and sync default to
    /// singleton `[1]` / `[sync]`, leaving historical grids
    /// untouched). Schedule/plan combinations an axis cannot satisfy
    /// (e.g. interleaved on a pp=1 plan, a microbatch count not
    /// divisible by pp, or an ep that doesn't divide dp/n_experts)
    /// fail validation and are skipped, not errors.
    pub fn expand(&self) -> Vec<StudyPoint> {
        let mut points = Vec::new();
        for arch in &self.archs {
            for &hw in &self.hws {
                for &nodes in &self.nodes {
                    let cluster = Cluster::new(hw, nodes);
                    for &seq in &self.seqs {
                        for &sharding in &self.shardings {
                            for &schedule in &self.schedules {
                                for &prefetch in &self.prefetch {
                                    self.expand_cluster(
                                        arch, cluster, seq, sharding,
                                        schedule, prefetch, &mut points);
                                }
                            }
                        }
                    }
                }
            }
        }
        points
    }

    #[allow(clippy::too_many_arguments)]
    fn expand_cluster(
        &self,
        arch: &TransformerArch,
        cluster: Cluster,
        seq_len: usize,
        sharding: Sharding,
        schedule: Schedule,
        prefetch: bool,
        points: &mut Vec<StudyPoint>,
    ) {
        let mem_bytes = cluster.node.spec().mem_bytes;
        for base_plan in self.plans.expand(&cluster, arch.n_layers) {
            // A fixed plan that already names an expert-parallel
            // degree keeps it (once); the eps axis crosses the rest.
            let fixed_ep = [base_plan.ep];
            let ep_axis: &[usize] = if base_plan.ep > 1 {
                &fixed_ep
            } else {
                &self.eps
            };
            for &ep in ep_axis {
                let plan = base_plan.with_ep(ep);
                let gbs_list: Vec<usize> = match &self.batches {
                    BatchAxis::Fixed(v) => v.clone(),
                    BatchAxis::PerReplica(factor) => vec![factor * plan.dp],
                };
                for gbs in gbs_list {
                    if plan.dp == 0 || gbs % plan.dp != 0 {
                        continue;
                    }
                    let local = gbs / plan.dp;
                    let mbs_list: Vec<usize> = match &self.micro {
                        MicroBatchAxis::Fixed(v) => v.clone(),
                        MicroBatchAxis::Divisors => divisors(local),
                    };
                    for mbs in mbs_list {
                        if mbs == 0 || mbs > local || local % mbs != 0 {
                            continue;
                        }
                        for &sync in &self.syncs {
                            let cfg = SimConfig {
                                arch: *arch,
                                cluster,
                                plan,
                                global_batch: gbs,
                                micro_batch: mbs,
                                seq_len,
                                sharding,
                                schedule,
                                prefetch,
                                jitter: self.jitter,
                                sync,
                                relia: self.relia,
                            };
                            if cfg.validate().is_err() {
                                continue;
                            }
                            let mem = memory::per_gpu_memory_cfg(&cfg);
                            if let Some(frac) = self.mem_cap_frac {
                                if mem.total() > mem_bytes * frac {
                                    continue;
                                }
                            }
                            points.push(StudyPoint {
                                cfg,
                                mem_per_gpu: mem.total(),
                            });
                        }
                    }
                }
            }
        }
    }
}

/// Fluent builder for [`Study`]. Every setter *replaces* its axis.
#[derive(Debug, Clone)]
pub struct StudyBuilder {
    name: String,
    title: String,
    archs: Vec<TransformerArch>,
    hws: Vec<HwId>,
    nodes: Vec<usize>,
    plans: PlanAxis,
    batches: BatchAxis,
    micro: MicroBatchAxis,
    seqs: Vec<usize>,
    shardings: Vec<Sharding>,
    schedules: Vec<Schedule>,
    prefetch: Vec<bool>,
    mem_cap_frac: Option<f64>,
    jitter: Jitter,
    eps: Vec<usize>,
    syncs: Vec<SyncMode>,
    relia: Reliability,
}

impl StudyBuilder {
    pub fn title(mut self, title: &str) -> Self {
        self.title = title.to_string();
        self
    }

    pub fn arch(self, arch: TransformerArch) -> Self {
        self.archs([arch])
    }

    pub fn archs(mut self, archs: impl IntoIterator<Item = TransformerArch>) -> Self {
        self.archs = archs.into_iter().collect();
        self
    }

    /// The hardware axis: any mix of built-in generations and loaded
    /// catalog entries (each grid point's cluster takes its
    /// NVLink-domain size, memory cap, and power model from the
    /// entry's spec).
    pub fn hardware(mut self, hws: impl IntoIterator<Item = HwId>) -> Self {
        self.hws = hws.into_iter().collect();
        self
    }

    /// Single-entry [`Self::hardware`] (historical name).
    pub fn generation(self, hw: HwId) -> Self {
        self.hardware([hw])
    }

    /// Alias for [`Self::hardware`] (historical name).
    pub fn generations(self, hws: impl IntoIterator<Item = HwId>) -> Self {
        self.hardware(hws)
    }

    /// Cluster sizes in nodes (NVLink domains: 8 GPUs per DGX node,
    /// 72 per GB200 NVL72 rack, whatever the catalog entry declares).
    pub fn nodes(mut self, nodes: impl IntoIterator<Item = usize>) -> Self {
        self.nodes = nodes.into_iter().collect();
        self
    }

    pub fn plans(mut self, plans: PlanAxis) -> Self {
        self.plans = plans;
        self
    }

    pub fn plan(self, plan: ParallelPlan) -> Self {
        self.plans(PlanAxis::Fixed(vec![plan]))
    }

    pub fn plan_shapes(self, shapes: &[(usize, usize, usize)]) -> Self {
        self.plans(PlanAxis::Shapes(shapes.to_vec()))
    }

    pub fn global_batches(mut self, gbs: impl IntoIterator<Item = usize>) -> Self {
        self.batches = BatchAxis::Fixed(gbs.into_iter().collect());
        self
    }

    /// Weak scaling: global batch = `per_replica` × dp.
    pub fn batch_per_replica(mut self, per_replica: usize) -> Self {
        self.batches = BatchAxis::PerReplica(per_replica);
        self
    }

    pub fn micro_batches(mut self, mbs: impl IntoIterator<Item = usize>) -> Self {
        self.micro = MicroBatchAxis::Fixed(mbs.into_iter().collect());
        self
    }

    /// Try every divisor of the per-replica batch.
    pub fn micro_batch_divisors(mut self) -> Self {
        self.micro = MicroBatchAxis::Divisors;
        self
    }

    pub fn seq_len(self, seq: usize) -> Self {
        self.seq_lens([seq])
    }

    pub fn seq_lens(mut self, seqs: impl IntoIterator<Item = usize>) -> Self {
        self.seqs = seqs.into_iter().collect();
        self
    }

    pub fn sharding(self, sharding: Sharding) -> Self {
        self.shardings([sharding])
    }

    pub fn shardings(mut self, shardings: impl IntoIterator<Item = Sharding>) -> Self {
        self.shardings = shardings.into_iter().collect();
        self
    }

    /// Pin the pipeline schedule axis to one schedule.
    pub fn schedule(self, schedule: Schedule) -> Self {
        self.schedules([schedule])
    }

    /// Sweep pipeline schedules (e.g. plain vs interleaved-1F1B).
    /// Combinations a plan cannot satisfy are skipped at expansion.
    pub fn schedules(mut self, schedules: impl IntoIterator<Item = Schedule>) -> Self {
        self.schedules = schedules.into_iter().collect();
        self
    }

    pub fn prefetch(mut self, on: bool) -> Self {
        self.prefetch = vec![on];
        self
    }

    /// Evaluate both with and without explicit FSDP prefetch (§3).
    pub fn prefetch_ablation(mut self) -> Self {
        self.prefetch = vec![true, false];
        self
    }

    /// Drop grid points whose per-GPU memory exceeds `frac` of device
    /// HBM (the planner's feasibility filter uses 0.94).
    pub fn memory_cap(mut self, frac: f64) -> Self {
        self.mem_cap_frac = Some(frac);
        self
    }

    /// Pin the expert-parallel degree to one value (applied to every
    /// plan on the plan axis via [`ParallelPlan::with_ep`]; points
    /// where it doesn't divide dp or `n_experts`, or where the arch is
    /// dense and ep > 1, are skipped at expansion).
    pub fn ep(self, ep: usize) -> Self {
        self.eps([ep])
    }

    /// Sweep expert-parallel degrees.
    pub fn eps(mut self, eps: impl IntoIterator<Item = usize>) -> Self {
        self.eps = eps.into_iter().collect();
        self
    }

    /// Pin the gradient-synchronization axis to one discipline
    /// (docs/moe.md; the default is [`SyncMode::Sync`], the exact
    /// historical code path).
    pub fn sync_mode(self, sync: SyncMode) -> Self {
        self.sync_modes([sync])
    }

    /// Sweep synchronization disciplines (e.g. sync vs `async:4`).
    pub fn sync_modes(mut self, syncs: impl IntoIterator<Item = SyncMode>) -> Self {
        self.syncs = syncs.into_iter().collect();
        self
    }

    /// Arm the failure/checkpoint axis: every grid point's
    /// `goodput_wps` discounts raw throughput by the availability
    /// under this checkpoint cadence ([`CkptInterval::Auto`] is the
    /// Young–Daly optimum; docs/reliability.md). The simulated
    /// iteration itself is untouched — like the async staleness
    /// discount, this is a render-time factor.
    pub fn checkpoint(mut self, ckpt: CkptInterval) -> Self {
        self.relia.ckpt = ckpt;
        self
    }

    /// Override the per-GPU MTBF (hours) from the hardware spec's
    /// `mtbf_hours` for every point in the grid. Requires an armed
    /// [`Self::checkpoint`] axis.
    pub fn mtbf_override(mut self, hours: f64) -> Self {
        self.relia.mtbf_hours = Some(hours);
        self
    }

    /// Elastic-membership mode: a failed rank shrinks the DP group
    /// until rejoin instead of gang-restarting the job, so only
    /// `1/dp` of the cluster pays each failure's rollback + repair.
    /// Requires an armed [`Self::checkpoint`] axis and a
    /// bounded-staleness sync axis (`SyncMode::Async`).
    pub fn elastic(mut self, on: bool) -> Self {
        self.relia.elastic = on;
        self
    }

    /// Arm the stochastic network-jitter axis: every grid point is
    /// simulated with per-op slowdown factors drawn from `dist`
    /// (docs/network.md). Combine with [`Self::seed`] /
    /// [`Self::seeds`]; leaving it unarmed keeps the study on the
    /// bit-exact deterministic path.
    pub fn jitter(mut self, dist: JitterDist) -> Self {
        self.jitter.dist = dist;
        self
    }

    /// Base seed for the armed jitter distribution. Deliberately
    /// shared across every config in the grid (common random numbers):
    /// config A vs config B under seed 7 differ only by the configs,
    /// not by draw luck.
    pub fn seed(mut self, seed: u64) -> Self {
        self.jitter.seed = seed;
        self
    }

    /// Evaluate each config as a distribution over `n` replicates
    /// (seeds derived from the base seed via
    /// [`crate::sim::replicate_seed`]); `CaseResult` then reports
    /// p50/p95/p99 iteration time over the replicates.
    pub fn seeds(mut self, n: u32) -> Self {
        self.jitter.replicates = n;
        self
    }

    /// Build, panicking on a malformed axis declaration (programmer
    /// error — figure definitions are static). Use [`Self::try_build`]
    /// for user-supplied grids.
    pub fn build(self) -> Study {
        match self.try_build() {
            Ok(s) => s,
            Err(e) => panic!("invalid study: {e}"),
        }
    }

    pub fn try_build(self) -> Result<Study, String> {
        if self.archs.is_empty() {
            return Err(format!("study '{}' declares no architecture", self.name));
        }
        if self.hws.is_empty() || self.nodes.is_empty()
            || self.seqs.is_empty() || self.shardings.is_empty()
            || self.schedules.is_empty() || self.prefetch.is_empty()
        {
            return Err(format!("study '{}' has an empty axis", self.name));
        }
        for s in &self.schedules {
            if let Schedule::Interleaved { v } = s {
                if *v < 2 {
                    return Err(format!(
                        "study '{}': interleaved schedule needs v >= 2, \
                         got {v}", self.name));
                }
            }
        }
        if self.nodes.iter().any(|&n| n == 0) {
            return Err("node counts must be >= 1".into());
        }
        if let Some(frac) = self.mem_cap_frac {
            if !(frac > 0.0 && frac <= 1.0) {
                return Err(format!("memory cap {frac} outside (0, 1]"));
            }
        }
        self.jitter
            .validate()
            .map_err(|e| format!("study '{}': {e}", self.name))?;
        if self.eps.is_empty() || self.syncs.is_empty() {
            return Err(format!("study '{}' has an empty axis", self.name));
        }
        if self.eps.iter().any(|&ep| ep == 0) {
            return Err(format!(
                "study '{}': expert-parallel degree must be >= 1", self.name));
        }
        for sync in &self.syncs {
            sync.validate()
                .map_err(|e| format!("study '{}': {e}", self.name))?;
        }
        self.relia
            .validate()
            .map_err(|e| format!("study '{}': {e}", self.name))?;
        if self.relia.elastic && self.syncs.iter().any(|s| s.is_sync()) {
            // Per-point validation would silently drop the Sync points
            // (expand skips invalid configs); an elastic study mixing
            // in Sync modes is a declaration error, not a sparse grid.
            return Err(format!(
                "study '{}': --elastic requires every sync-axis entry \
                 to be bounded-staleness (--sync async:K)", self.name));
        }
        Ok(Study {
            name: self.name,
            title: self.title,
            archs: self.archs,
            hws: self.hws,
            nodes: self.nodes,
            plans: self.plans,
            batches: self.batches,
            micro: self.micro,
            seqs: self.seqs,
            shardings: self.shardings,
            schedules: self.schedules,
            prefetch: self.prefetch,
            mem_cap_frac: self.mem_cap_frac,
            jitter: self.jitter,
            eps: self.eps,
            syncs: self.syncs,
            relia: self.relia,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::LLAMA_7B;

    #[test]
    fn divisors_enumerates_all() {
        assert_eq!(divisors(1), vec![1]);
        assert_eq!(divisors(12), vec![1, 2, 3, 4, 6, 12]);
        assert_eq!(divisors(48), vec![1, 2, 3, 4, 6, 8, 12, 16, 24, 48]);
        assert_eq!(divisors(7), vec![1, 7]);
        assert!(divisors(0).is_empty());
    }

    #[test]
    fn weak_scaling_study_expands_one_point_per_scale() {
        let s = Study::builder("weak")
            .arch(LLAMA_7B)
            .nodes([1, 2, 4])
            .batch_per_replica(2)
            .micro_batches([2])
            .build();
        let pts = s.expand();
        assert_eq!(pts.len(), 3);
        for (p, nodes) in pts.iter().zip([1usize, 2, 4]) {
            assert_eq!(p.cfg.cluster.nodes, nodes);
            assert_eq!(p.cfg.plan.dp, nodes * 8);
            assert_eq!(p.cfg.global_batch, 2 * nodes * 8);
            assert!(p.mem_per_gpu > 0.0);
        }
    }

    #[test]
    fn sweep_with_divisors_covers_odd_batch_shapes() {
        // gbs 48 on 16 GPUs: dp 16 leaves a local batch of 3, which the
        // old hardcoded {1,2,4,8} candidate set silently skipped.
        let s = Study::builder("odd")
            .arch(LLAMA_7B)
            .nodes([2])
            .plans(PlanAxis::Sweep { with_cp: false })
            .global_batches([48])
            .micro_batch_divisors()
            .memory_cap(0.94)
            .build();
        let pts = s.expand();
        assert!(pts.iter().any(|p| p.cfg.plan.dp == 16 && p.cfg.micro_batch == 3),
                "divisor enumeration must try mbs=3 at dp=16");
        for p in &pts {
            let local = p.cfg.global_batch / p.cfg.plan.dp;
            assert_eq!(local % p.cfg.micro_batch, 0);
        }
    }

    #[test]
    fn memory_cap_filters_points() {
        let uncapped = Study::builder("u")
            .arch(LLAMA_7B)
            .nodes([1])
            .plans(PlanAxis::Sweep { with_cp: false })
            .global_batches([64])
            .micro_batch_divisors()
            .build()
            .expand();
        let capped = Study::builder("c")
            .arch(LLAMA_7B)
            .nodes([1])
            .plans(PlanAxis::Sweep { with_cp: false })
            .global_batches([64])
            .micro_batch_divisors()
            .memory_cap(0.94)
            .build()
            .expand();
        assert!(capped.len() < uncapped.len(),
                "{} !< {}", capped.len(), uncapped.len());
        let cap = 80e9 * 0.94;
        for p in &capped {
            assert!(p.mem_per_gpu <= cap);
        }
    }

    #[test]
    fn shapes_axis_derives_dp() {
        let s = Study::builder("shapes")
            .arch(LLAMA_7B)
            .nodes([4])
            .plan_shapes(&[(1, 1, 1), (2, 1, 1), (1, 4, 1)])
            .global_batches([64])
            .micro_batches([1])
            .build();
        let plans: Vec<ParallelPlan> =
            s.expand().iter().map(|p| p.cfg.plan).collect();
        assert_eq!(plans, vec![
            ParallelPlan::new(32, 1, 1, 1),
            ParallelPlan::new(16, 2, 1, 1),
            ParallelPlan::new(8, 1, 4, 1),
        ]);
    }

    #[test]
    fn config_key_distinguishes_custom_archs_sharing_a_name() {
        let custom = TransformerArch { d_ff: 8192, ..LLAMA_7B };
        let cluster = Cluster::new(HwId::H100, 1);
        let mk = |arch| SimConfig::fsdp(
            arch, cluster, ParallelPlan::data_parallel(8), 16, 2, 4096);
        assert_ne!(ConfigKey::of(&mk(LLAMA_7B)), ConfigKey::of(&mk(custom)),
                   "same-name archs with different shapes must not alias");
        assert_eq!(ConfigKey::of(&mk(custom)), ConfigKey::of(&mk(custom)));
    }

    #[test]
    fn schedule_axis_expands_and_filters() {
        // schedules × plans: interleaved points survive only where
        // pp >= 2, layers divide into pp·v chunks, and m % pp == 0.
        let s = Study::builder("sched")
            .arch(LLAMA_7B)
            .nodes([2])
            .plan_shapes(&[(1, 1, 1), (1, 4, 1)])
            .global_batches([32])
            .micro_batches([1, 2])
            .schedules([Schedule::OneFOneB,
                        Schedule::Interleaved { v: 2 }])
            .build();
        let pts = s.expand();
        // pp=1 plan: 1f1b only. pp=4 plan (dp=4, local 8): m = 8 or 4,
        // both divisible by 4 → both schedules.
        assert!(pts.iter().all(|p| match p.cfg.schedule {
            Schedule::Interleaved { .. } => p.cfg.plan.pp > 1,
            Schedule::OneFOneB => true,
        }));
        let il: Vec<_> = pts.iter()
            .filter(|p| p.cfg.schedule != Schedule::OneFOneB)
            .collect();
        assert_eq!(il.len(), 2, "pp=4 × mbs {{1,2}} interleaved points");
        for p in &il {
            assert_eq!(p.cfg.microbatches() % p.cfg.plan.pp, 0);
        }
        // Interleaved points carry deeper activation residency.
        let plain = pts.iter().find(|p| {
            p.cfg.plan.pp == 4 && p.cfg.micro_batch == 1
                && p.cfg.schedule == Schedule::OneFOneB
        }).unwrap();
        let inter = pts.iter().find(|p| {
            p.cfg.plan.pp == 4 && p.cfg.micro_batch == 1
                && p.cfg.schedule != Schedule::OneFOneB
        }).unwrap();
        assert!(inter.mem_per_gpu > plain.mem_per_gpu);
    }

    #[test]
    fn builder_rejects_degenerate_interleaving() {
        assert!(Study::builder("bad-v")
            .arch(LLAMA_7B)
            .schedules([Schedule::Interleaved { v: 1 }])
            .try_build()
            .is_err());
    }

    #[test]
    fn pinned_sched_bench_grid_covers_the_new_axes() {
        let pts = bench_pinned_sched_study().expand();
        assert!(!pts.is_empty());
        assert!(pts.iter().any(
            |p| matches!(p.cfg.schedule, Schedule::Interleaved { .. })));
        assert!(pts.iter().any(
            |p| p.cfg.sharding == Sharding::Zero3));
    }

    #[test]
    fn pinned_hw_bench_grid_covers_every_builtin() {
        let pts = bench_pinned_hw_study().expand();
        assert!(!pts.is_empty());
        for hw in HwId::ALL {
            assert!(pts.iter().any(|p| p.cfg.cluster.node.gpu == hw),
                    "pinned hw grid missing {hw}");
        }
        // GB200 points really use the 72-GPU NVLink domain.
        assert!(pts.iter().any(|p| p.cfg.cluster.gpus_per_node() == 72));
    }

    #[test]
    fn hardware_axis_spans_catalog_entries() {
        use crate::hardware::{Catalog, GpuSpec, HwSpec};
        // A fat-fabric H100 variant registered at test time behaves
        // like a built-in on the axis: same grid shape, different
        // numbers, per-spec memory cap.
        let custom = Catalog::register(HwSpec {
            name: "study-fat-ib".into(),
            gpus_per_node: 8,
            gpu: GpuSpec {
                name: "study-fat-ib",
                ib_bw: 1600e9,
                ..crate::hardware::specs::H100.clone()
            },
            freq_curve: None,
            fabric: crate::hardware::FabricSpec::DEDICATED,
            reliability: crate::hardware::ReliabilitySpec::DEFAULT,
            derived: false,
        }).unwrap();
        let s = Study::builder("hw-axis")
            .arch(LLAMA_7B)
            .hardware([HwId::H100, custom])
            .nodes([2])
            .batch_per_replica(2)
            .micro_batches([2])
            .build();
        let pts = s.expand();
        assert_eq!(pts.len(), 2);
        assert_eq!(pts[0].cfg.cluster.node.gpu, HwId::H100);
        assert_eq!(pts[1].cfg.cluster.node.gpu, custom);
        // Same workload, distinct dedup keys.
        assert_ne!(ConfigKey::of(&pts[0].cfg), ConfigKey::of(&pts[1].cfg));
    }

    #[test]
    fn config_key_distinguishes_every_axis() {
        let s = Study::builder("k")
            .arch(LLAMA_7B)
            .nodes([1, 2])
            .batch_per_replica(2)
            .micro_batches([1, 2])
            .build();
        let pts = s.expand();
        let keys: std::collections::HashSet<ConfigKey> =
            pts.iter().map(|p| ConfigKey::of(&p.cfg)).collect();
        assert_eq!(keys.len(), pts.len());
    }

    #[test]
    fn seed_axis_hashes_into_config_key() {
        // The ResultStore dedup regression (ISSUE 8 satellite): the
        // same workload under different seeds, replicate counts, or
        // distributions must never share a cache key, while the same
        // armed spec keys identically.
        let grid = |seed: u64, n: u32| {
            Study::builder("seeded")
                .arch(LLAMA_7B)
                .nodes([1])
                .batch_per_replica(2)
                .micro_batches([2])
                .jitter(JitterDist::Lognormal { sigma: 0.2 })
                .seed(seed)
                .seeds(n)
                .build()
                .expand()
        };
        let k = |pts: &[StudyPoint]| ConfigKey::of(&pts[0].cfg);
        let a = k(&grid(7, 4));
        assert_eq!(a, k(&grid(7, 4)));
        assert_ne!(a, k(&grid(8, 4)), "seeds must not alias");
        assert_ne!(a, k(&grid(7, 8)), "replicate counts must not alias");
        let off = Study::builder("off")
            .arch(LLAMA_7B)
            .nodes([1])
            .batch_per_replica(2)
            .micro_batches([2])
            .build()
            .expand();
        assert_ne!(a, k(&off), "armed and off must not alias");
        // Expansion stamps the armed jitter onto every point.
        assert_eq!(grid(7, 4)[0].cfg.jitter.seed, 7);
        assert_eq!(grid(7, 4)[0].cfg.jitter.replicates, 4);
        assert!(off[0].cfg.jitter.is_off());
    }

    #[test]
    fn builder_rejects_seed_without_armed_jitter() {
        // Jitter::validate keeps the off spec canonical so store keys
        // never alias; the builder surfaces that at build time.
        let err = Study::builder("seed-off")
            .arch(LLAMA_7B)
            .seed(7)
            .try_build()
            .unwrap_err();
        assert!(err.contains("jitter=off"), "{err}");
        assert!(Study::builder("reps-off")
            .arch(LLAMA_7B)
            .seeds(4)
            .try_build()
            .is_err());
        assert!(Study::builder("bad-sigma")
            .arch(LLAMA_7B)
            .jitter(JitterDist::Lognormal { sigma: -1.0 })
            .try_build()
            .is_err());
    }

    #[test]
    fn pinned_stochastic_bench_grid_is_armed() {
        let s = bench_pinned_stochastic_study();
        assert_eq!(s.jitter().replicates, 8);
        assert_eq!(s.jitter().seed, 7);
        let pts = s.expand();
        assert!(!pts.is_empty());
        assert!(pts.iter().all(|p| !p.cfg.jitter.is_off()));
    }

    #[test]
    fn ep_axis_expands_only_feasible_shards() {
        use crate::model::LLAMA_7B_MOE8X;
        // 1 node = 8 GPUs. dp=8 admits ep {1,2,4,8}; ep=16 fails
        // validation (doesn't divide dp) and is skipped, not an error.
        let pts = Study::builder("ep")
            .arch(LLAMA_7B_MOE8X)
            .nodes([1])
            .global_batches([16])
            .micro_batches([2])
            .eps([1, 2, 4, 8, 16])
            .build()
            .expand();
        let eps: Vec<usize> = pts.iter().map(|p| p.cfg.plan.ep).collect();
        assert_eq!(eps, vec![1, 2, 4, 8]);
        // World size never changes: EP re-uses the DP ranks.
        assert!(pts.iter().all(|p| p.cfg.plan.world_size() == 8));
        // Sharding experts over more ranks strictly shrinks residency.
        for w in pts.windows(2) {
            assert!(w[1].mem_per_gpu < w[0].mem_per_gpu,
                    "ep={} should hold less than ep={}",
                    w[1].cfg.plan.ep, w[0].cfg.plan.ep);
        }
    }

    #[test]
    fn ep_axis_skips_dense_archs() {
        // ep > 1 on a dense model fails cfg.validate() and drops out of
        // the grid; ep = 1 survives untouched.
        let pts = Study::builder("dense-ep")
            .arch(LLAMA_7B)
            .nodes([1])
            .batch_per_replica(2)
            .micro_batches([2])
            .eps([1, 2])
            .build()
            .expand();
        assert_eq!(pts.len(), 1);
        assert_eq!(pts[0].cfg.plan.ep, 1);
    }

    #[test]
    fn sync_axis_expands_and_keys_distinctly() {
        let pts = Study::builder("sync")
            .arch(LLAMA_7B)
            .nodes([1])
            .batch_per_replica(2)
            .micro_batches([2])
            .sync_modes([SyncMode::Sync,
                         SyncMode::Async { max_staleness: 4 }])
            .build()
            .expand();
        assert_eq!(pts.len(), 2);
        assert_eq!(pts[0].cfg.sync, SyncMode::Sync);
        assert_eq!(pts[1].cfg.sync, SyncMode::Async { max_staleness: 4 });
        // The store must never answer an async table from a sync run.
        assert_ne!(ConfigKey::of(&pts[0].cfg), ConfigKey::of(&pts[1].cfg));
        // Different staleness bounds must not alias either.
        let mut c = pts[1].cfg;
        c.sync = SyncMode::Async { max_staleness: 8 };
        assert_ne!(ConfigKey::of(&pts[1].cfg), ConfigKey::of(&c));
    }

    #[test]
    fn builder_rejects_degenerate_sync_and_ep_axes() {
        assert!(Study::builder("async0")
            .arch(LLAMA_7B)
            .sync_modes([SyncMode::Async { max_staleness: 0 }])
            .try_build()
            .is_err());
        assert!(Study::builder("ep0")
            .arch(LLAMA_7B)
            .eps([0])
            .try_build()
            .is_err());
        assert!(Study::builder("empty-sync")
            .arch(LLAMA_7B)
            .sync_modes(Vec::<SyncMode>::new())
            .try_build()
            .is_err());
    }

    #[test]
    fn pinned_moe_bench_grid_covers_the_new_axes() {
        let pts = bench_pinned_moe_study().expand();
        assert!(!pts.is_empty());
        assert!(pts.iter().all(|p| p.cfg.arch.is_moe()));
        assert!(pts.iter().any(|p| p.cfg.plan.ep == 8));
        assert!(pts.iter().any(|p| !p.cfg.sync.is_sync()));
        assert!(pts.iter().any(|p| p.cfg.sync.is_sync()));
    }

    #[test]
    fn builder_rejects_bad_input() {
        assert!(Study::builder("no-arch").try_build().is_err());
        assert!(Study::builder("bad-cap")
            .arch(LLAMA_7B)
            .memory_cap(1.5)
            .try_build()
            .is_err());
        assert!(Study::builder("zero-nodes")
            .arch(LLAMA_7B)
            .nodes([0])
            .try_build()
            .is_err());
    }

    #[test]
    fn reliability_axis_hashes_into_config_key() {
        // Same store-aliasing discipline as the seed axis: a goodput
        // table under one checkpoint cadence / MTBF / membership mode
        // must never answer for another.
        let grid = |relia: Reliability| {
            let mut b = Study::builder("relia")
                .arch(LLAMA_7B)
                .nodes([1])
                .batch_per_replica(2)
                .micro_batches([2])
                .checkpoint(relia.ckpt)
                .elastic(relia.elastic);
            if relia.elastic {
                b = b.sync_modes([SyncMode::Async { max_staleness: 4 }]);
            }
            if let Some(h) = relia.mtbf_hours {
                b = b.mtbf_override(h);
            }
            b.build().expand()
        };
        let k = |pts: &[StudyPoint]| ConfigKey::of(&pts[0].cfg);
        let auto = Reliability {
            ckpt: CkptInterval::Auto, mtbf_hours: None, elastic: false };
        let a = k(&grid(auto));
        assert_eq!(a, k(&grid(auto)));
        assert_ne!(a, k(&grid(Reliability {
            ckpt: CkptInterval::Every { seconds: 1800.0 }, ..auto })),
            "cadences must not alias");
        assert_ne!(
            k(&grid(Reliability {
                ckpt: CkptInterval::Every { seconds: 1800.0 }, ..auto })),
            k(&grid(Reliability {
                ckpt: CkptInterval::Every { seconds: 3600.0 }, ..auto })),
            "intervals must not alias");
        assert_ne!(a, k(&grid(Reliability {
            mtbf_hours: Some(10_000.0), ..auto })),
            "MTBF overrides must not alias");
        assert_ne!(a, k(&grid(Reliability { elastic: true, ..auto })),
            "membership modes must not alias");
        let off = Study::builder("relia-off")
            .arch(LLAMA_7B)
            .nodes([1])
            .batch_per_replica(2)
            .micro_batches([2])
            .build()
            .expand();
        assert_ne!(a, k(&off), "armed and off must not alias");
        assert!(off[0].cfg.relia.is_off());
        assert_eq!(grid(auto)[0].cfg.relia.ckpt, CkptInterval::Auto);
    }

    #[test]
    fn builder_rejects_mtbf_or_elastic_without_armed_ckpt() {
        // Reliability::validate keeps the off spec canonical so store
        // keys never alias; the builder surfaces that at build time.
        let err = Study::builder("mtbf-off")
            .arch(LLAMA_7B)
            .mtbf_override(30_000.0)
            .try_build()
            .unwrap_err();
        assert!(err.contains("arm --ckpt"), "{err}");
        assert!(Study::builder("elastic-off")
            .arch(LLAMA_7B)
            .sync_modes([SyncMode::Async { max_staleness: 4 }])
            .elastic(true)
            .try_build()
            .is_err());
        assert!(Study::builder("bad-interval")
            .arch(LLAMA_7B)
            .checkpoint(CkptInterval::Every { seconds: 0.0 })
            .try_build()
            .is_err());
    }

    #[test]
    fn builder_rejects_elastic_with_sync_axis_entries() {
        // A per-point skip would silently shrink the grid; the builder
        // rejects the declaration instead.
        let err = Study::builder("elastic-sync")
            .arch(LLAMA_7B)
            .checkpoint(CkptInterval::Auto)
            .elastic(true)
            .sync_modes([SyncMode::Sync,
                         SyncMode::Async { max_staleness: 4 }])
            .try_build()
            .unwrap_err();
        assert!(err.contains("async"), "{err}");
        // All-async elastic builds fine and stamps every point.
        let pts = Study::builder("elastic-ok")
            .arch(LLAMA_7B)
            .nodes([1])
            .batch_per_replica(2)
            .micro_batches([2])
            .checkpoint(CkptInterval::Auto)
            .elastic(true)
            .sync_modes([SyncMode::Async { max_staleness: 4 }])
            .build()
            .expand();
        assert!(!pts.is_empty());
        assert!(pts.iter().all(|p| p.cfg.relia.elastic));
    }
}
