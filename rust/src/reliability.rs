//! Failure-aware goodput: the availability model behind the
//! `goodput_wps` column and `Objective::GoodputWps`
//! (docs/reliability.md has the full derivation).
//!
//! A cluster of `n` GPUs with per-GPU MTBF `m` hours fails as a series
//! system: `MTBF_cluster = m·3600/n` seconds. Between failures the job
//! checkpoints every `I` seconds, stalling `δ = ckpt_bytes/ckpt_bw`
//! per checkpoint; each failure rolls back `I/2` of work on average
//! and pays `R` seconds of restart + rendezvous. The steady-state
//! wasted-time fraction is additive:
//!
//! ```text
//! waste(I) = δ/I + e·(I/2 + R)/MTBF_cluster
//! ```
//!
//! where `e` is the elastic-churn factor: 1 for a gang-scheduled job
//! (the whole cluster rolls back and waits), `1/dp` when `--elastic`
//! rides on bounded-staleness DP (only the failed replica's slice of
//! the cluster reloads and rejoins; the surviving `dp−1` replicas keep
//! stepping). `d waste/dI = −δ/I² + e/(2·MTBF)` vanishes at the
//! Young–Daly optimum `I* = sqrt(2·MTBF_cluster·δ/e)` — the exact
//! minimizer of the modeled waste, which the `auto` cadence uses and a
//! closed-form test pins. `availability = max(0, 1 − waste)` and
//! `goodput_wps = global_wps · availability`.
//!
//! Everything here is a render-time discount — the simulated iteration
//! is untouched (the PR 9 `effective_wps` precedent), so the unarmed
//! path stays bit-identical on both engines and the armed path needs
//! no new engine cases.

use crate::hardware::ReliabilitySpec;
use crate::sim::{CkptInterval, Reliability};

/// Cluster MTBF in seconds: per-GPU MTBF (hours) over `world` GPUs in
/// series.
pub fn cluster_mtbf_s(mtbf_gpu_hours: f64, world: usize) -> f64 {
    mtbf_gpu_hours * 3600.0 / world as f64
}

/// Young–Daly optimal checkpoint interval, seconds: the exact
/// minimizer of `waste(I) = δ/I + e·(I/2 + R)/M` — `sqrt(2·M·δ/e)`,
/// the textbook `sqrt(2·MTBF·δ)` when `elastic_frac == 1`.
pub fn young_daly_interval(
    mtbf_s: f64, t_ckpt_s: f64, elastic_frac: f64,
) -> f64 {
    (2.0 * mtbf_s * t_ckpt_s / elastic_frac).sqrt()
}

/// Fraction of wall-clock time spent on useful work under checkpoint
/// interval `interval_s`, clamped to `[0, 1]` (a cluster can be so
/// failure-dominated that no interval yields forward progress).
pub fn availability(
    interval_s: f64,
    t_ckpt_s: f64,
    t_repair_s: f64,
    mtbf_s: f64,
    elastic_frac: f64,
) -> f64 {
    let waste = t_ckpt_s / interval_s
        + elastic_frac * (interval_s / 2.0 + t_repair_s) / mtbf_s;
    (1.0 - waste).clamp(0.0, 1.0)
}

/// The elastic-churn cost factor: `1/dp` when a failed rank shrinks
/// the DP group until rejoin, 1 when the whole job gang-restarts.
pub fn elastic_frac(relia: &Reliability, dp: usize) -> f64 {
    if relia.elastic { 1.0 / dp.max(1) as f64 } else { 1.0 }
}

/// The checkpoint cadence a case actually runs, seconds: the explicit
/// interval, or the Young–Daly optimum for [`CkptInterval::Auto`].
/// `None` when the reliability axis is off.
pub fn resolved_interval_s(
    relia: &Reliability,
    spec: &ReliabilitySpec,
    world: usize,
    dp: usize,
    ckpt_bytes: f64,
) -> Option<f64> {
    match relia.ckpt {
        CkptInterval::Off => None,
        CkptInterval::Every { seconds } => Some(seconds),
        CkptInterval::Auto => {
            let mtbf_s = cluster_mtbf_s(
                relia.mtbf_hours.unwrap_or(spec.mtbf_hours), world);
            let t_ckpt = ckpt_bytes / spec.ckpt_bw;
            Some(young_daly_interval(
                mtbf_s, t_ckpt, elastic_frac(relia, dp)))
        }
    }
}

/// The multiplicative goodput discount for one case: exactly 1.0 when
/// the reliability axis is off (so the unarmed `goodput_wps` column
/// equals the raw one bit for bit), otherwise the availability under
/// the case's cadence, hardware reliability figures, and world size.
pub fn goodput_factor(
    relia: &Reliability,
    spec: &ReliabilitySpec,
    world: usize,
    dp: usize,
    ckpt_bytes: f64,
) -> f64 {
    let Some(interval) =
        resolved_interval_s(relia, spec, world, dp, ckpt_bytes)
    else {
        return 1.0;
    };
    let mtbf_s = cluster_mtbf_s(
        relia.mtbf_hours.unwrap_or(spec.mtbf_hours), world);
    let t_ckpt = ckpt_bytes / spec.ckpt_bw;
    availability(
        interval,
        t_ckpt,
        spec.restart_s + spec.rendezvous_s,
        mtbf_s,
        elastic_frac(relia, dp),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    const SPEC: ReliabilitySpec = ReliabilitySpec::DEFAULT;

    fn armed(mtbf_hours: f64) -> Reliability {
        Reliability {
            ckpt: CkptInterval::Auto,
            mtbf_hours: Some(mtbf_hours),
            elastic: false,
        }
    }

    #[test]
    fn young_daly_auto_matches_the_closed_form() {
        // The acceptance-criteria pin: `auto` is exactly
        // sqrt(2 · MTBF_cluster · t_ckpt), bit for bit.
        let world = 1024;
        let dp = 128;
        let ckpt_bytes = 2.0e10;
        let relia = armed(30_000.0);
        let interval = resolved_interval_s(
            &relia, &SPEC, world, dp, ckpt_bytes).unwrap();
        let mtbf_s = 30_000.0 * 3600.0 / world as f64;
        let t_ckpt = ckpt_bytes / SPEC.ckpt_bw;
        assert_eq!(interval.to_bits(),
                   (2.0 * mtbf_s * t_ckpt).sqrt().to_bits());
    }

    #[test]
    fn auto_interval_minimizes_the_modeled_waste() {
        let world = 4096;
        let dp = 512;
        let ckpt_bytes = 5.0e10;
        let relia = armed(20_000.0);
        let mtbf_s = cluster_mtbf_s(20_000.0, world);
        let t_ckpt = ckpt_bytes / SPEC.ckpt_bw;
        let best = resolved_interval_s(
            &relia, &SPEC, world, dp, ckpt_bytes).unwrap();
        let repair = SPEC.restart_s + SPEC.rendezvous_s;
        let at = |i: f64| availability(i, t_ckpt, repair, mtbf_s, 1.0);
        for frac in [0.25, 0.5, 0.8, 1.25, 2.0, 4.0] {
            assert!(at(best) >= at(best * frac),
                    "I*={best} beaten at {}x", frac);
        }
    }

    #[test]
    fn availability_declines_with_world_size() {
        // The goodput cliff: at fixed per-GPU MTBF, cluster MTBF
        // shrinks as 1/n, so availability strictly declines even at
        // each world's own optimal interval.
        let relia = armed(50_000.0);
        let mut prev = f64::INFINITY;
        for world in [256usize, 1024, 4096, 16384, 65536] {
            let a = goodput_factor(&relia, &SPEC, world, world / 8,
                                   1.0e10);
            assert!(a < prev, "world {world}: {a} !< {prev}");
            assert!(a > 0.0 && a <= 1.0);
            prev = a;
        }
    }

    #[test]
    fn elastic_churn_discounts_the_failure_term() {
        let world = 8192;
        let dp = 1024;
        let ckpt_bytes = 2.0e10;
        let gang = Reliability {
            ckpt: CkptInterval::Every { seconds: 1800.0 },
            mtbf_hours: Some(10_000.0),
            elastic: false,
        };
        let elastic = Reliability { elastic: true, ..gang };
        let a_gang = goodput_factor(&gang, &SPEC, world, dp, ckpt_bytes);
        let a_el =
            goodput_factor(&elastic, &SPEC, world, dp, ckpt_bytes);
        assert!(a_el > a_gang, "{a_el} !> {a_gang}");
        // At a fixed interval, only the failure term shrinks (by 1/dp);
        // the checkpoint-stall term is shared.
        let mtbf_s = cluster_mtbf_s(10_000.0, world);
        let t_ckpt = ckpt_bytes / SPEC.ckpt_bw;
        let repair = SPEC.restart_s + SPEC.rendezvous_s;
        let expect = (a_gang
            + (1.0 - 1.0 / dp as f64) * (1800.0 / 2.0 + repair) / mtbf_s)
            .min(1.0);
        assert!((a_el - expect).abs() < 1e-12, "{a_el} vs {expect}");
        // ...and the elastic optimum stretches by sqrt(dp).
        let auto_gang = Reliability {
            ckpt: CkptInterval::Auto, ..gang };
        let auto_el = Reliability {
            ckpt: CkptInterval::Auto, elastic: true, ..gang };
        let i_gang = resolved_interval_s(
            &auto_gang, &SPEC, world, dp, ckpt_bytes).unwrap();
        let i_el = resolved_interval_s(
            &auto_el, &SPEC, world, dp, ckpt_bytes).unwrap();
        assert!((i_el / i_gang - (dp as f64).sqrt()).abs() < 1e-9);
    }

    #[test]
    fn off_axis_is_exactly_one() {
        let f = goodput_factor(
            &Reliability::OFF, &SPEC, 8192, 1024, 1.0e12);
        assert_eq!(f.to_bits(), 1.0f64.to_bits());
        assert_eq!(resolved_interval_s(
            &Reliability::OFF, &SPEC, 8192, 1024, 1.0e12), None);
    }

    #[test]
    fn failure_dominated_clusters_clamp_to_zero() {
        // An absurdly unreliable fleet: availability floors at 0
        // instead of going negative (goodput_wps stays a throughput).
        let relia = Reliability {
            ckpt: CkptInterval::Every { seconds: 10.0 },
            mtbf_hours: Some(0.001),
            elastic: false,
        };
        let a = goodput_factor(&relia, &SPEC, 65536, 8192, 1.0e11);
        assert_eq!(a, 0.0);
    }
}
