//! Analytical NCCL collective cost model (paper §2.2, Figure 2).
//!
//! Models the algorithms NCCL actually uses on DGX clusters:
//!
//! * **AllGather / ReduceScatter** — ring only. `(n-1)` steps; the
//!   bottleneck link is per-node InfiniBand shared by the group members
//!   on each node once the ring leaves the node. Ring efficiency decays
//!   with node count (protocol/straggler effects) — this is what makes
//!   FSDP latency-bound at scale (Fig. 2b, Fig. 4).
//! * **AllReduce** — min(ring, double-binary-tree). The tree keeps busbw
//!   roughly flat-to-improving with node count (Fig. 2a), which is why
//!   vanilla DDP and TP collectives scale so much better than FSDP's.
//! * **Point-to-point** — pipeline activations.
//!
//! Times are seconds; sizes bytes. The α (latency) and η (efficiency
//! decay) constants are calibrated against the paper's Figure 2 shapes
//! and the NCCL-tests numbers the figure reports; see CALIBRATION below.

use std::collections::HashMap;

use crate::hardware::HwId;
use crate::topology::{Cluster, GroupPlacement};

/// Collective operations used by the training stack.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Collective {
    AllReduce,
    AllGather,
    ReduceScatter,
    Broadcast,
    AllToAll,
    /// One-directional send/recv between pipeline stages.
    PointToPoint,
}

impl std::fmt::Display for Collective {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            Collective::AllReduce => "AllReduce",
            Collective::AllGather => "AllGather",
            Collective::ReduceScatter => "ReduceScatter",
            Collective::Broadcast => "Broadcast",
            Collective::AllToAll => "AllToAll",
            Collective::PointToPoint => "P2P",
        };
        write!(f, "{s}")
    }
}

/// Cost of one collective invocation.
#[derive(Debug, Clone, Copy)]
pub struct CommCost {
    pub time_s: f64,
    /// NCCL-style bus bandwidth (algorithm-normalized), bytes/s.
    pub busbw: f64,
    /// Algorithm the model selected.
    pub algo: Algo,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Algo {
    Ring,
    Tree,
    Direct,
    Local,
}

// --- CALIBRATION -----------------------------------------------------------
// Base per-step latencies (NCCL Simple protocol with chunk pipelining —
// effective per-ring-step startup, not the raw wire latency):
const ALPHA_NVLINK: f64 = 1.2e-6; // intra-node hop
const ALPHA_IB: f64 = 5.5e-6; // inter-node hop
// Fabric protocol efficiency on large messages (fraction of datasheet bw).
const LINK_EFF: f64 = 0.90;
// Ring efficiency decay with node count: eta = 1/(1 + C_RING·ln(nodes)) —
// straggler/jitter accumulation over the (n-1)-step synchronous ring.
// Jointly calibrated with ALPHA_IB against: Fig. 2b busbw decay, the
// §4.1 "-37.22% from 128→2048 GPUs" headline, and the §5 observation
// that exposure becomes unavoidable beyond ~128 GPUs.
const C_RING: f64 = 0.08;
// Tree efficiency *rises* with node count as pipelining amortizes
// (Fig. 2a): eta_tree = TREE_BASE + TREE_SLOPE·log2(nodes), capped at 1.
const TREE_BASE: f64 = 0.70;
const TREE_SLOPE: f64 = 0.035;
// ---------------------------------------------------------------------------

/// Per-rank inter-node bandwidth for a group: the node's InfiniBand
/// shared by the group members on each node (the contention factor from
/// the placement), derated by the catalog fabric's oversubscription and
/// co-scheduled background load
/// ([`FabricSpec::inter_node_bw`](crate::hardware::FabricSpec)). On the
/// default dedicated fabric every derate is exactly 1.0, so this is
/// bit-identical to the plain `ib_bw / ranks_per_node` share.
fn inter_node_bw(cluster: &Cluster, place: &GroupPlacement) -> f64 {
    cluster.node.hw_spec().fabric
        .inter_node_bw(cluster.node.spec().ib_bw, place.ranks_per_node)
}

/// Effective per-rank ring bandwidth for a group placed on the cluster.
/// Intra-node rings ride NVLink; once the ring spans nodes, every member
/// on a node shares that node's InfiniBand for the inter-node hops.
fn ring_bandwidth(cluster: &Cluster, place: &GroupPlacement) -> f64 {
    let gpu = cluster.node.spec();
    if !place.crosses_nodes {
        gpu.nvlink_bw * LINK_EFF
    } else {
        let ib_share = inter_node_bw(cluster, place);
        ib_share.min(gpu.nvlink_bw) * LINK_EFF
    }
}

fn ring_eta(place: &GroupPlacement) -> f64 {
    if place.nodes <= 1 {
        1.0
    } else {
        1.0 / (1.0 + C_RING * (place.nodes as f64).ln())
    }
}

fn tree_eta(place: &GroupPlacement) -> f64 {
    let n = place.nodes.max(1) as f64;
    (TREE_BASE + TREE_SLOPE * n.log2()).min(1.0)
}

fn alpha(place: &GroupPlacement) -> f64 {
    if place.crosses_nodes { ALPHA_IB } else { ALPHA_NVLINK }
}

/// Time for a ring AllGather/ReduceScatter moving `bytes` total payload
/// (i.e. the unsharded tensor size) across `place`.
fn ring_ag_rs(bytes: f64, cluster: &Cluster, place: &GroupPlacement)
    -> CommCost
{
    let n = place.size as f64;
    if place.size <= 1 {
        return CommCost { time_s: 0.0, busbw: f64::INFINITY,
                          algo: Algo::Local };
    }
    let bw = ring_bandwidth(cluster, place) * ring_eta(place);
    let data = bytes * (n - 1.0) / n;
    let time = (n - 1.0) * alpha(place) + data / bw;
    CommCost { time_s: time, busbw: data / time, algo: Algo::Ring }
}

/// Ring AllReduce = ReduceScatter + AllGather (2(n-1) steps).
fn ring_allreduce(bytes: f64, cluster: &Cluster, place: &GroupPlacement)
    -> CommCost
{
    let n = place.size as f64;
    let bw = ring_bandwidth(cluster, place) * ring_eta(place);
    let data = 2.0 * bytes * (n - 1.0) / n;
    let time = 2.0 * (n - 1.0) * alpha(place) + data / bw;
    CommCost { time_s: time, busbw: data / time, algo: Algo::Ring }
}

/// Double-binary-tree AllReduce: 2·log2 latency steps, each byte crosses
/// the bottleneck twice (up + down), with efficiency that improves with
/// scale as NCCL pipelines chunks through the trees.
fn tree_allreduce(bytes: f64, cluster: &Cluster, place: &GroupPlacement)
    -> CommCost
{
    let n = place.size as f64;
    let gpu = cluster.node.spec();
    let link = if place.crosses_nodes {
        inter_node_bw(cluster, place).min(gpu.nvlink_bw)
    } else {
        gpu.nvlink_bw
    } * LINK_EFF;
    let bw = link * tree_eta(place);
    let steps = 2.0 * n.log2().ceil().max(1.0);
    let time = steps * alpha(place) + 2.0 * bytes / bw;
    // busbw convention for AllReduce: 2·(n-1)/n · S / t.
    let busdata = 2.0 * bytes * (n - 1.0) / n;
    CommCost { time_s: time, busbw: busdata / time, algo: Algo::Tree }
}

/// Cost of `coll` moving `bytes` (unsharded tensor size) over a group.
pub fn collective_time(
    coll: Collective,
    bytes: f64,
    cluster: &Cluster,
    place: &GroupPlacement,
) -> CommCost {
    if place.size <= 1 && coll != Collective::PointToPoint {
        return CommCost { time_s: 0.0, busbw: f64::INFINITY,
                          algo: Algo::Local };
    }
    match coll {
        Collective::AllGather | Collective::ReduceScatter => {
            ring_ag_rs(bytes, cluster, place)
        }
        Collective::AllReduce => {
            let ring = ring_allreduce(bytes, cluster, place);
            let tree = tree_allreduce(bytes, cluster, place);
            if ring.time_s <= tree.time_s { ring } else { tree }
        }
        Collective::Broadcast => {
            // Tree broadcast: log2 hops, payload crosses once.
            let gpu = cluster.node.spec();
            let bw = ring_bandwidth(cluster, place);
            let steps = (place.size as f64).log2().ceil().max(1.0);
            let time = steps * alpha(place) + bytes / bw;
            let _ = gpu;
            CommCost { time_s: time, busbw: bytes / time, algo: Algo::Tree }
        }
        Collective::AllToAll => {
            // Each rank exchanges bytes/n with every peer; bottleneck is
            // the per-rank share of the slowest fabric.
            let n = place.size as f64;
            let bw = ring_bandwidth(cluster, place);
            let data = bytes * (n - 1.0) / n;
            let time = (n - 1.0) * alpha(place) + data / bw;
            CommCost { time_s: time, busbw: data / time, algo: Algo::Direct }
        }
        Collective::PointToPoint => {
            let gpu = cluster.node.spec();
            let (a, bw) = if place.crosses_nodes {
                (ALPHA_IB, inter_node_bw(cluster, place))
            } else {
                (ALPHA_NVLINK, gpu.nvlink_bw)
            };
            let time = a + bytes / (bw * LINK_EFF);
            CommCost { time_s: time, busbw: bytes / time, algo: Algo::Direct }
        }
    }
}

/// Memoization key for [`collective_time`]. The model depends on the
/// cluster only through the interned hardware id (whose immutable
/// catalog spec fixes NVLink/IB bandwidths and the node shape) and on
/// the group only through its [`GroupPlacement`]; the payload is keyed
/// by its exact f64 bits so a hit is guaranteed to be the result of an
/// identical call. `HwId` is `Copy + Hash`, so custom catalog entries
/// key exactly as cheaply as the old `Generation` enum did.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct CostKey {
    coll: Collective,
    bytes_bits: u64,
    hw: HwId,
    place: GroupPlacement,
}

/// Memo cache for [`collective_time`], shared per worker by the study
/// runner: neighboring grid points (same plan, different microbatch or
/// global batch; same placement across figures) re-derive identical
/// ring/tree costs thousands of times in a sweep. Results are stored
/// verbatim, so a cached [`CommCost`] is bit-identical to the uncached
/// call — simulation output cannot change by enabling the cache.
#[derive(Debug, Default)]
pub struct CostCache {
    map: HashMap<CostKey, CommCost>,
    hits: u64,
    misses: u64,
}

impl CostCache {
    pub fn new() -> CostCache {
        CostCache::default()
    }

    /// `collective_time` through the memo.
    pub fn get(
        &mut self,
        coll: Collective,
        bytes: f64,
        cluster: &Cluster,
        place: &GroupPlacement,
    ) -> CommCost {
        // Keying by hardware id is sound only while every NodeSpec is
        // the canonical one for its catalog entry (true for all
        // Clusters built via `Cluster::new`; catalog specs are
        // immutable once registered); a hand-built NodeSpec would
        // silently alias cache entries otherwise.
        debug_assert_eq!(
            cluster.node.gpus_per_node,
            cluster.node.gpu.node().gpus_per_node,
            "CostCache assumes the canonical NodeSpec per hardware id");
        let key = CostKey {
            coll,
            bytes_bits: bytes.to_bits(),
            hw: cluster.node.gpu,
            place: *place,
        };
        if let Some(cost) = self.map.get(&key) {
            self.hits += 1;
            return *cost;
        }
        let cost = collective_time(coll, bytes, cluster, place);
        self.map.insert(key, cost);
        self.misses += 1;
        cost
    }

    /// (hits, misses) since construction.
    pub fn stats(&self) -> (u64, u64) {
        (self.hits, self.misses)
    }

    /// Distinct (collective, bytes, generation, placement) entries.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

/// Convenience: busbw in GB/s for the Fig. 2 reproduction.
pub fn busbw_gbps(
    coll: Collective,
    bytes: f64,
    cluster: &Cluster,
    place: &GroupPlacement,
) -> f64 {
    collective_time(coll, bytes, cluster, place).busbw / 1e9
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::Generation;

    fn h100(nodes: usize) -> Cluster {
        Cluster::new(Generation::H100, nodes)
    }

    fn full_cluster_group(c: &Cluster) -> GroupPlacement {
        GroupPlacement::strided(c, c.world_size(), 1)
    }

    const GB: f64 = 1e9;

    #[test]
    fn zero_time_for_singleton_groups() {
        let c = h100(1);
        let p = GroupPlacement::strided(&c, 1, 1);
        for coll in [Collective::AllReduce, Collective::AllGather,
                     Collective::ReduceScatter] {
            assert_eq!(collective_time(coll, GB, &c, &p).time_s, 0.0);
        }
    }

    #[test]
    fn intra_node_faster_than_inter_node() {
        let c1 = h100(1);
        let c2 = h100(2);
        let intra = collective_time(
            Collective::AllGather, GB, &c1,
            &GroupPlacement::strided(&c1, 8, 1));
        let inter = collective_time(
            Collective::AllGather, GB, &c2,
            &GroupPlacement::strided(&c2, 16, 1));
        assert!(intra.time_s < inter.time_s);
    }

    #[test]
    fn fig2b_allgather_busbw_decays_with_nodes() {
        // The paper's core communication observation: ring AllGather
        // busbw falls as world size grows.
        let sizes = [4usize, 16, 64, 256, 512];
        let mut prev = f64::INFINITY;
        for &nodes in &sizes {
            let c = h100(nodes);
            let bw = busbw_gbps(Collective::AllGather, 4.0 * GB, &c,
                                &full_cluster_group(&c));
            assert!(bw < prev, "busbw must decay: {bw} !< {prev}");
            prev = bw;
        }
        // And the overall decay is substantial (~2-3x from 4→512).
        let first = busbw_gbps(Collective::AllGather, GB, &h100(4),
                               &full_cluster_group(&h100(4)));
        let last = busbw_gbps(Collective::AllGather, GB, &h100(512),
                              &full_cluster_group(&h100(512)));
        let ratio = first / last;
        assert!(ratio > 1.5 && ratio < 4.0, "decay ratio {ratio}");
    }

    #[test]
    fn fig2a_allreduce_busbw_scales_well() {
        // Tree AllReduce busbw must NOT decay like the ring does.
        let at = |nodes: usize| {
            let c = h100(nodes);
            busbw_gbps(Collective::AllReduce, 4.0 * GB, &c,
                       &full_cluster_group(&c))
        };
        let small = at(4);
        let large = at(512);
        assert!(large > small * 0.9,
                "allreduce busbw should hold up: {small} -> {large}");
    }

    #[test]
    fn allreduce_picks_tree_at_scale_ring_when_small() {
        let c_small = h100(1);
        let small = collective_time(
            Collective::AllReduce, 100.0 * 1e6, &c_small,
            &GroupPlacement::strided(&c_small, 8, 1));
        assert_eq!(small.algo, Algo::Ring);

        let c_big = h100(128);
        let big = collective_time(
            Collective::AllReduce, 100.0 * 1e6, &c_big,
            &full_cluster_group(&c_big));
        assert_eq!(big.algo, Algo::Tree);
    }

    #[test]
    fn fig4_collective_time_grows_with_world_size() {
        // Fixed per-rank FSDP shard: total gathered bytes constant, group
        // grows — time must grow (latency + eta decay).
        let bytes = 13.0 * GB; // 7B params in bf16
        let mut prev = 0.0;
        for nodes in [1usize, 2, 4, 8, 16, 32, 64, 128, 256] {
            let c = h100(nodes);
            let t = collective_time(Collective::AllGather, bytes, &c,
                                    &full_cluster_group(&c)).time_s;
            assert!(t > prev, "time must grow with world size");
            prev = t;
        }
    }

    #[test]
    fn latency_bound_small_messages() {
        // Small message over many nodes: time ≈ (n-1)·alpha regardless
        // of size.
        let c = h100(64);
        let p = full_cluster_group(&c);
        let t_small = collective_time(Collective::AllGather, 1e3, &c, &p);
        let t_smaller = collective_time(Collective::AllGather, 1e2, &c, &p);
        let rel = (t_small.time_s - t_smaller.time_s) / t_small.time_s;
        assert!(rel.abs() < 0.05, "latency-bound regime: {rel}");
    }

    #[test]
    fn bandwidth_bound_large_messages_scale_linearly() {
        let c = h100(8);
        let p = full_cluster_group(&c);
        let t1 = collective_time(Collective::AllGather, 8.0 * GB, &c, &p);
        let t2 = collective_time(Collective::AllGather, 16.0 * GB, &c, &p);
        let ratio = t2.time_s / t1.time_s;
        assert!((ratio - 2.0).abs() < 0.1, "{ratio}");
    }

    #[test]
    fn p2p_intra_vs_inter() {
        let c = h100(2);
        let intra = collective_time(Collective::PointToPoint, GB, &c,
                                    &GroupPlacement::strided(&c, 2, 1));
        let inter = collective_time(Collective::PointToPoint, GB, &c,
                                    &GroupPlacement::strided(&c, 2, 8));
        assert!(intra.time_s < inter.time_s);
    }

    #[test]
    fn a100_fabric_slower_than_h100() {
        let ca = Cluster::new(Generation::A100, 16);
        let ch = h100(16);
        let ta = collective_time(Collective::AllGather, GB, &ca,
                                 &full_cluster_group(&ca)).time_s;
        let th = collective_time(Collective::AllGather, GB, &ch,
                                 &full_cluster_group(&ch)).time_s;
        assert!(ta > th);
    }

    #[test]
    fn cost_cache_hits_are_bit_identical() {
        let mut cache = CostCache::new();
        let c = h100(16);
        let p = full_cluster_group(&c);
        let direct = collective_time(Collective::AllGather, GB, &c, &p);
        for _ in 0..3 {
            let cached = cache.get(Collective::AllGather, GB, &c, &p);
            assert_eq!(cached.time_s.to_bits(), direct.time_s.to_bits());
            assert_eq!(cached.busbw.to_bits(), direct.busbw.to_bits());
            assert_eq!(cached.algo, direct.algo);
        }
        assert_eq!(cache.stats(), (2, 1));
        assert_eq!(cache.len(), 1);
        // Distinct payloads, ops, and generations are distinct entries.
        cache.get(Collective::AllGather, 2.0 * GB, &c, &p);
        cache.get(Collective::ReduceScatter, GB, &c, &p);
        let ca = Cluster::new(Generation::A100, 16);
        cache.get(Collective::AllGather, GB, &ca, &p);
        assert_eq!(cache.len(), 4);
    }

    #[test]
    fn fabric_derates_only_inter_node_bandwidth() {
        use crate::hardware::{Catalog, FabricKind, FabricSpec};
        let ft = |oversub, background_load| FabricSpec {
            kind: FabricKind::FatTree, oversub, background_load,
        };
        let shared =
            Catalog::with_fabric(Generation::H100, ft(2.0, 0.0)).unwrap();
        let c_ded = h100(16);
        let c_shared = Cluster::new(shared, 16);
        let p = full_cluster_group(&c_ded);
        // 2:1 oversubscription halves the bandwidth-bound portion of a
        // large inter-node transfer, so time roughly doubles.
        let bytes = 8.0 * GB;
        let t_ded =
            collective_time(Collective::AllGather, bytes, &c_ded, &p);
        let t_shared = collective_time(
            Collective::AllGather, bytes, &c_shared,
            &full_cluster_group(&c_shared));
        let ratio = t_shared.time_s / t_ded.time_s;
        assert!(ratio > 1.8 && ratio < 2.1, "{ratio}");
        // Intra-node groups ride NVLink and never see the fabric.
        let c1_ded = Cluster::new(Generation::H100, 1);
        let c1_shared = Cluster::new(shared, 1);
        let p1 = GroupPlacement::strided(&c1_ded, 8, 1);
        let a = collective_time(Collective::AllReduce, GB, &c1_ded, &p1);
        let b = collective_time(Collective::AllReduce, GB, &c1_shared, &p1);
        assert_eq!(a.time_s.to_bits(), b.time_s.to_bits());
        // Background load stacks multiplicatively on the oversub.
        let busy =
            Catalog::with_fabric(Generation::H100, ft(2.0, 0.5)).unwrap();
        let c_busy = Cluster::new(busy, 16);
        let t_busy = collective_time(
            Collective::AllGather, bytes, &c_busy,
            &full_cluster_group(&c_busy));
        assert!(t_busy.time_s > t_shared.time_s * 1.5);
        // P2P and tree AllReduce see the derate too.
        let c2_ded = h100(2);
        let c2_shared = Cluster::new(shared, 2);
        let p2 = GroupPlacement::strided(&c2_ded, 2, 8);
        let p2s = GroupPlacement::strided(&c2_shared, 2, 8);
        let p2p_d =
            collective_time(Collective::PointToPoint, GB, &c2_ded, &p2);
        let p2p_s =
            collective_time(Collective::PointToPoint, GB, &c2_shared, &p2s);
        assert!(p2p_s.time_s > p2p_d.time_s * 1.5);
    }

    #[test]
    fn dedicated_fabric_is_bit_identical_to_raw_share() {
        // The DEDICATED derates are exact 1.0 multiplies: the fabric
        // layer cannot move a single bit of the paper-pinned figures.
        use crate::hardware::FabricSpec;
        let c = h100(16);
        let p = full_cluster_group(&c);
        let raw = c.node.spec().ib_bw / p.ranks_per_node as f64;
        let derated = FabricSpec::DEDICATED
            .inter_node_bw(c.node.spec().ib_bw, p.ranks_per_node);
        assert_eq!(raw.to_bits(), derated.to_bits());
        assert_eq!(inter_node_bw(&c, &p).to_bits(), raw.to_bits());
    }

    #[test]
    fn reduce_scatter_equals_allgather_cost() {
        // Ring RS and AG are symmetric in this model (and in NCCL).
        let c = h100(8);
        let p = full_cluster_group(&c);
        let ag = collective_time(Collective::AllGather, GB, &c, &p).time_s;
        let rs = collective_time(Collective::ReduceScatter, GB, &c, &p)
            .time_s;
        assert!((ag - rs).abs() < 1e-12);
    }
}
