//! Per-GPU memory model for FSDP + model parallel training (Figure 14
//! and the planner's feasibility filter).
//!
//! Accounting follows PyTorch FSDPv2 with bf16 params/grads and fp32
//! AdamW state (m, v, master weights = 12 bytes/param), the paper's
//! training configuration (Appendix B: bf16, AdamW, no activation
//! checkpointing, FSDP without forward resharding).
//!
//! [`per_gpu_memory_for`] / [`per_gpu_memory_cfg`] extend the model to
//! the schedule and sharding axes: persistent state shards over the
//! sharding mode's actual shard group (full DP for FSDP/ZeRO-3, the
//! intra-group slice for HSDP, nothing for DDP), and activation
//! residency follows the pipeline schedule's in-flight chunk count
//! (`docs/scheduling.md` §Memory).

use crate::model::TransformerArch;
use crate::parallelism::ParallelPlan;
use crate::sim::{Schedule, Sharding, SimConfig};

/// Bytes per parameter of optimizer + master state in mixed precision:
/// fp32 master (4) + fp32 m (4) + fp32 v (4).
pub const OPT_BYTES_PER_PARAM: f64 = 12.0;
/// bf16 working parameters and gradients.
pub const PARAM_BYTES: f64 = 2.0;
pub const GRAD_BYTES: f64 = 2.0;
/// CUDA context + NCCL buffers + framework overhead (GB-scale constant).
pub const FRAMEWORK_OVERHEAD: f64 = 3.0e9;

/// Per-GPU memory breakdown, bytes.
#[derive(Debug, Clone, Copy)]
pub struct MemoryBreakdown {
    /// Persistent sharded parameter storage (FSDP shard of this rank's
    /// tp/pp partition).
    pub params_shard: f64,
    /// Sharded gradient storage.
    pub grads_shard: f64,
    /// Sharded optimizer + master-weight state.
    pub optimizer_shard: f64,
    /// Peak unsharded working set: FSDP keeps gathered parameters for
    /// the layers currently executing (current + prefetched next).
    pub unsharded_working: f64,
    /// Stored activations for backward (scales with in-flight
    /// microbatches under pipeline parallelism).
    pub activations: f64,
    /// Logits + loss workspace on the last stage.
    pub logits: f64,
    /// Fixed framework overhead.
    pub overhead: f64,
}

impl MemoryBreakdown {
    pub fn total(&self) -> f64 {
        self.params_shard + self.grads_shard + self.optimizer_shard
            + self.unsharded_working + self.activations + self.logits
            + self.overhead
    }
}

/// Memory use for one GPU under `plan`, with `micro_batch` sequences per
/// microbatch and `in_flight` microbatches resident (1 without pipeline;
/// up to `pp` with 1F1B). The historical FSDP/1F1B entry point; the
/// schedule- and sharding-aware model is [`per_gpu_memory_for`].
pub fn per_gpu_memory(
    arch: &TransformerArch,
    plan: &ParallelPlan,
    micro_batch: usize,
    seq_len: usize,
    in_flight: usize,
) -> MemoryBreakdown {
    breakdown(arch, plan, micro_batch, seq_len, plan.dp as f64, true,
              1.0, in_flight.max(1) as f64)
}

/// In-flight activation *chunks* resident on the worst-case (first)
/// pipeline device:
///
/// * 1F1B: `min(m, pp)` full per-stage activations;
/// * interleaved-1F1B: warmup `2(pp-1) + (v-1)·pp` chunk-activations
///   plus the one entering steady state, capped at `m·v` — each chunk
///   `1/v` of a stage's layers (`docs/scheduling.md` §Memory).
pub fn in_flight_chunks(
    schedule: Schedule,
    pp: usize,
    microbatches: usize,
) -> usize {
    match schedule {
        Schedule::OneFOneB => microbatches.min(pp).max(1),
        Schedule::Interleaved { v } => {
            (2 * pp.saturating_sub(1) + (v - 1) * pp + 1)
                .min(microbatches * v)
                .max(1)
        }
    }
}

/// Schedule- and sharding-aware per-GPU memory: persistent state
/// shards over the mode's actual shard group (DDP replicates, HSDP
/// shards within `group` ranks, FSDP/ZeRO-3 over the full DP group),
/// and activation residency follows the schedule's in-flight chunks.
pub fn per_gpu_memory_for(
    arch: &TransformerArch,
    plan: &ParallelPlan,
    micro_batch: usize,
    seq_len: usize,
    sharding: Sharding,
    schedule: Schedule,
    microbatches: usize,
) -> MemoryBreakdown {
    let shard_deg = match sharding {
        Sharding::Fsdp | Sharding::Zero3 => plan.dp,
        Sharding::Hsdp { group } => group.clamp(1, plan.dp),
        Sharding::Ddp => 1,
    } as f64;
    // DDP keeps parameters fully resident (no gathered working set);
    // the sharded modes gather two layers (current + prefetched next).
    let gathers = !matches!(sharding, Sharding::Ddp);
    let chunks = in_flight_chunks(schedule, plan.pp, microbatches);
    breakdown(arch, plan, micro_batch, seq_len, shard_deg, gathers,
              schedule.chunks() as f64, chunks as f64)
}

/// [`per_gpu_memory_for`] on a full simulation config.
pub fn per_gpu_memory_cfg(cfg: &SimConfig) -> MemoryBreakdown {
    per_gpu_memory_for(&cfg.arch, &cfg.plan, cfg.micro_batch,
                       cfg.seq_len, cfg.sharding, cfg.schedule,
                       cfg.microbatches())
}

/// Shared accounting core. `chunk_div` is the virtual-chunk divisor of
/// a stage's layer count (1 for plain 1F1B) and `in_flight_chunks` the
/// resident chunk-activation count.
#[allow(clippy::too_many_arguments)]
fn breakdown(
    arch: &TransformerArch,
    plan: &ParallelPlan,
    micro_batch: usize,
    seq_len: usize,
    shard_deg: f64,
    gathers: bool,
    chunk_div: f64,
    in_flight_chunks: f64,
) -> MemoryBreakdown {
    let mp = (plan.tp * plan.pp) as f64;
    // This rank's tp/pp slice of the parameters it is responsible for:
    // with expert parallelism only `1/ep` of the experts are resident
    // (attention and router replicated); `params_ep` routes to the
    // historical `params()` expression verbatim for dense models.
    let params_partition = arch.params_ep(plan.ep) / mp;
    let shard = params_partition / shard_deg;

    let layers_per_stage = (arch.n_layers as f64 / plan.pp as f64).ceil();
    // Gathered working set: two layers' worth of full (tp-sliced) params
    // (explicit prefetch keeps the next layer's AllGather in flight).
    // FSDP gathers only this rank's expert shard — remote experts are
    // reached by dispatching tokens (AllToAll), never by gathering
    // their weights.
    let unsharded = if gathers {
        2.0 * arch.layer_param_bytes_ep(plan.ep) / plan.tp as f64
    } else {
        0.0
    };

    let act_layer = arch.activation_bytes_per_layer(
        micro_batch as f64, seq_len as f64)
        / (plan.tp as f64 * plan.cp as f64);
    let activations =
        act_layer * (layers_per_stage / chunk_div) * in_flight_chunks;

    // Last pipeline stage holds logits in fp32 for the loss.
    let logits = if plan.pp == 1 {
        4.0 * micro_batch as f64 * seq_len as f64 * arch.vocab as f64
            / plan.tp as f64
    } else {
        0.0 // amortized into the last stage; keep the common-path shape
    };

    MemoryBreakdown {
        params_shard: PARAM_BYTES * shard,
        grads_shard: GRAD_BYTES * shard,
        optimizer_shard: OPT_BYTES_PER_PARAM * shard,
        unsharded_working: unsharded,
        activations,
        logits,
        overhead: FRAMEWORK_OVERHEAD,
    }
}

/// Per-GPU bytes a checkpoint must persist: this rank's shard of the
/// bf16 parameters plus the fp32 optimizer/master state, under the
/// sharding mode's actual shard group (every rank writes its own shard
/// — the standard distributed-checkpoint layout). Gradients,
/// activations, and the gathered working set are not checkpointed.
/// Pure function of (arch, plan, sharding), so the reliability layer
/// recomputes it identically from a store key and from a live config
/// (docs/reliability.md).
pub fn ckpt_bytes_per_gpu(
    arch: &TransformerArch,
    plan: &ParallelPlan,
    sharding: Sharding,
) -> f64 {
    let shard_deg = match sharding {
        Sharding::Fsdp | Sharding::Zero3 => plan.dp,
        Sharding::Hsdp { group } => group.clamp(1, plan.dp),
        Sharding::Ddp => 1,
    } as f64;
    let shard =
        arch.params_ep(plan.ep) / (plan.tp * plan.pp) as f64 / shard_deg;
    (PARAM_BYTES + OPT_BYTES_PER_PARAM) * shard
}

/// Does the plan fit in device memory (with a safety margin)?
pub fn fits(
    arch: &TransformerArch,
    plan: &ParallelPlan,
    micro_batch: usize,
    seq_len: usize,
    in_flight: usize,
    mem_bytes: f64,
) -> bool {
    per_gpu_memory(arch, plan, micro_batch, seq_len, in_flight).total()
        <= mem_bytes * 0.94 // leave headroom for fragmentation
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{LLAMA_70B, LLAMA_7B};

    #[test]
    fn fig14_memory_decreases_with_dp_but_saturates() {
        // Paper Fig. 14: memory falls as dp grows; savings diminish.
        let mut prev_total = f64::INFINITY;
        let mut prev_saving = f64::INFINITY;
        let mut totals = Vec::new();
        for dp in [8usize, 16, 32, 64, 128, 256] {
            let plan = ParallelPlan::data_parallel(dp);
            let m = per_gpu_memory(&LLAMA_7B, &plan, 2, 4096, 1).total();
            assert!(m < prev_total);
            let saving = prev_total - m;
            if prev_total.is_finite() {
                assert!(saving < prev_saving,
                        "savings must diminish: {saving} !< {prev_saving}");
                prev_saving = saving;
            }
            prev_total = m;
            totals.push(m);
        }
        // Floor: activations + overhead never shard away.
        let floor = totals.last().unwrap();
        assert!(*floor > FRAMEWORK_OVERHEAD);
    }

    #[test]
    fn seven_b_fits_8_gpus_but_not_one() {
        let h100 = 80e9;
        // dp=8: 7B trains on a single DGX (as in practice).
        assert!(fits(&LLAMA_7B, &ParallelPlan::data_parallel(8), 2, 4096,
                     1, h100));
        // dp=1: 16 bytes/param alone is ~108 GB — cannot fit.
        assert!(!fits(&LLAMA_7B, &ParallelPlan::data_parallel(1), 2, 4096,
                      1, h100));
    }

    #[test]
    fn seventy_b_needs_model_parallelism_at_small_scale() {
        let h100 = 80e9;
        // 70B on 64 GPUs pure FSDP: 16 B/param /64 ≈ 17.5 GB state alone,
        // plus ~2.3 GB unsharded working set and activations — fits only
        // with model parallelism once activations are accounted.
        let pure = ParallelPlan::data_parallel(64);
        let mp = ParallelPlan::new(16, 4, 1, 1);
        let m_pure = per_gpu_memory(&LLAMA_70B, &pure, 2, 4096, 1).total();
        let m_mp = per_gpu_memory(&LLAMA_70B, &mp, 2, 4096, 1).total();
        assert!(m_mp < m_pure);
        let _ = h100;
    }

    #[test]
    fn tp_shards_activations_and_working_set() {
        let base = per_gpu_memory(
            &LLAMA_7B, &ParallelPlan::data_parallel(64), 2, 4096, 1);
        let tp4 = per_gpu_memory(
            &LLAMA_7B, &ParallelPlan::new(16, 4, 1, 1), 2, 4096, 1);
        assert!(tp4.activations < base.activations);
        assert!(tp4.unsharded_working < base.unsharded_working);
    }

    #[test]
    fn pipeline_in_flight_microbatches_grow_activations() {
        let plan = ParallelPlan::new(16, 1, 4, 1);
        let one = per_gpu_memory(&LLAMA_7B, &plan, 2, 4096, 1);
        let four = per_gpu_memory(&LLAMA_7B, &plan, 2, 4096, 4);
        assert!((four.activations / one.activations - 4.0).abs() < 1e-9);
    }

    #[test]
    fn schedule_aware_memory_matches_legacy_for_fsdp_1f1b() {
        // The sharding/schedule-aware model must be bit-identical to
        // the historical FSDP path on the historical axes (study CSV
        // bytes depend on it).
        for (plan, mbs, m) in [
            (ParallelPlan::data_parallel(64), 2usize, 1usize),
            (ParallelPlan::new(8, 2, 2, 1), 2, 4),
            (ParallelPlan::new(8, 1, 4, 1), 1, 8),
        ] {
            let legacy = per_gpu_memory(
                &LLAMA_7B, &plan, mbs, 4096, m.min(plan.pp));
            let aware = per_gpu_memory_for(
                &LLAMA_7B, &plan, mbs, 4096, Sharding::Fsdp,
                Schedule::OneFOneB, m);
            assert_eq!(legacy.total().to_bits(), aware.total().to_bits());
            assert_eq!(legacy.activations.to_bits(),
                       aware.activations.to_bits());
        }
    }

    #[test]
    fn interleaved_activation_residency() {
        assert_eq!(in_flight_chunks(Schedule::OneFOneB, 4, 8), 4);
        // warmup 2(pp-1) + (v-1)·pp, plus the chunk entering steady
        // state: 6 + 4 + 1 = 11, under the m·v = 16 cap.
        assert_eq!(in_flight_chunks(Schedule::Interleaved { v: 2 }, 4, 8),
                   11);
        // capped by total chunk count when m is small.
        assert_eq!(in_flight_chunks(Schedule::Interleaved { v: 2 }, 4, 4),
                   8);
        let plan = ParallelPlan::new(8, 1, 4, 1);
        let base = per_gpu_memory_for(
            &LLAMA_7B, &plan, 1, 4096, Sharding::Fsdp,
            Schedule::OneFOneB, 8);
        let il = per_gpu_memory_for(
            &LLAMA_7B, &plan, 1, 4096, Sharding::Fsdp,
            Schedule::Interleaved { v: 2 }, 8);
        // 11 half-stage chunks (5.5 stage-equivalents) vs 4 stages.
        assert!(il.activations > base.activations);
        assert!((il.activations / base.activations - 5.5 / 4.0).abs()
                < 1e-9);
    }

    #[test]
    fn sharding_modes_shard_persistent_state_differently() {
        let plan = ParallelPlan::data_parallel(64);
        let mk = |s| per_gpu_memory_for(
            &LLAMA_7B, &plan, 2, 4096, s, Schedule::OneFOneB, 1);
        let fsdp = mk(Sharding::Fsdp);
        let hsdp = mk(Sharding::Hsdp { group: 8 });
        let ddp = mk(Sharding::Ddp);
        let zero3 = mk(Sharding::Zero3);
        // DDP replicates optimizer state; HSDP shards only within the
        // group; FSDP/ZeRO-3 shard over the full DP world.
        assert!(fsdp.optimizer_shard < hsdp.optimizer_shard);
        assert!(hsdp.optimizer_shard < ddp.optimizer_shard);
        assert_eq!(ddp.unsharded_working, 0.0);
        assert_eq!(zero3.total().to_bits(), fsdp.total().to_bits());
    }

    #[test]
    fn ep_sharded_memory_residency_pin() {
        use crate::model::LLAMA_7B_MOE8X;
        // 7b-moe8x, dp=8, ep=8, tp=pp=1, FSDP:
        //   params_ep(8) = 262,144,000
        //     + 32·(67,117,056 + 32,768 + 1,082,130,432/8) + 4,096
        //     = 262,144,000 + 32·202,416,128 + 4,096 = 6,739,464,192
        //   shard = /8 = 842,433,024 → params_shard = 1,684,866,048
        //   unsharded = 2·layer_param_bytes_ep(8) = 809,664,512
        let plan = ParallelPlan::data_parallel(8).with_ep(8);
        let m = per_gpu_memory_for(&LLAMA_7B_MOE8X, &plan, 2, 4096,
                                   Sharding::Fsdp, Schedule::OneFOneB, 1);
        assert_eq!(m.params_shard, 1_684_866_048.0);
        assert_eq!(m.unsharded_working, 809_664_512.0);
        // EP monotonically reduces residency; ep=1 replicates all
        // experts on every rank.
        let rep = per_gpu_memory_for(&LLAMA_7B_MOE8X, &plan.with_ep(1),
                                     2, 4096, Sharding::Fsdp,
                                     Schedule::OneFOneB, 1);
        assert!(m.total() < rep.total());
    }

    #[test]
    fn ep_is_inert_for_dense_models() {
        let plan = ParallelPlan::data_parallel(8);
        let base = per_gpu_memory_for(&LLAMA_7B, &plan, 2, 4096,
                                      Sharding::Fsdp, Schedule::OneFOneB,
                                      1);
        let ep = per_gpu_memory_for(&LLAMA_7B, &plan.with_ep(4), 2, 4096,
                                    Sharding::Fsdp, Schedule::OneFOneB,
                                    1);
        assert_eq!(base.total().to_bits(), ep.total().to_bits());
    }

    #[test]
    fn ckpt_bytes_follow_the_persistent_shard() {
        let plan = ParallelPlan::data_parallel(64);
        let m = per_gpu_memory_for(&LLAMA_7B, &plan, 2, 4096,
                                   Sharding::Fsdp, Schedule::OneFOneB, 1);
        let ckpt = ckpt_bytes_per_gpu(&LLAMA_7B, &plan, Sharding::Fsdp);
        // Exactly the persistent params + optimizer shards — grads,
        // activations, and the gathered working set are excluded.
        assert_eq!(ckpt.to_bits(),
                   (m.params_shard + m.optimizer_shard).to_bits());
        // DDP persists the full replica; FSDP 1/dp of it.
        let ddp = ckpt_bytes_per_gpu(&LLAMA_7B, &plan, Sharding::Ddp);
        assert!((ddp / ckpt - 64.0).abs() < 1e-9);
        // HSDP shards within the group only.
        let hsdp = ckpt_bytes_per_gpu(
            &LLAMA_7B, &plan, Sharding::Hsdp { group: 8 });
        assert!((hsdp / ckpt - 8.0).abs() < 1e-9);
    }

    #[test]
    fn breakdown_sums_to_total() {
        let m = per_gpu_memory(
            &LLAMA_7B, &ParallelPlan::new(8, 2, 2, 1), 2, 4096, 2);
        let sum = m.params_shard + m.grads_shard + m.optimizer_shard
            + m.unsharded_working + m.activations + m.logits + m.overhead;
        assert!((sum - m.total()).abs() < 1.0);
    }
}
