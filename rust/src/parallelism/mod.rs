//! Parallelization plans: FSDP data parallelism composed with tensor,
//! pipeline, and context model parallelism (§2.1 of the paper).
//!
//! Rank layout follows the Megatron convention — tensor parallel
//! innermost (consecutive ranks, NVLink-adjacent), then context parallel,
//! then pipeline stages, then data parallel outermost:
//!
//!   rank = dp·(pp·cp·tp) + pp_idx·(cp·tp) + cp_idx·tp + tp_idx
//!
//! A key consequence the paper exploits (§4.3): FSDP collectives run over
//! the *data-parallel group only*, of size world/(tp·pp·cp), so model
//! parallelism shrinks the AllGather/ReduceScatter world size.

use crate::topology::{Cluster, GroupPlacement};

/// Degrees of each parallelism dimension.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ParallelPlan {
    /// Data parallel (FSDP) degree.
    pub dp: usize,
    /// Tensor parallel degree.
    pub tp: usize,
    /// Pipeline parallel degree.
    pub pp: usize,
    /// Context (sequence) parallel degree.
    pub cp: usize,
    /// Expert parallel degree (MoE). EP reuses data-parallel ranks —
    /// each DP group of size `dp` is tiled into `dp/ep` expert shards
    /// — so `ep` must divide `dp` and does not change the world size.
    /// `ep = 1` (dense / fully replicated experts) is the default.
    pub ep: usize,
}

impl ParallelPlan {
    pub fn data_parallel(dp: usize) -> ParallelPlan {
        ParallelPlan { dp, tp: 1, pp: 1, cp: 1, ep: 1 }
    }

    pub fn new(dp: usize, tp: usize, pp: usize, cp: usize) -> ParallelPlan {
        ParallelPlan { dp, tp, pp, cp, ep: 1 }
    }

    /// The plan with expert parallelism `ep` (builder-style).
    pub fn with_ep(self, ep: usize) -> ParallelPlan {
        ParallelPlan { ep, ..self }
    }

    pub fn world_size(&self) -> usize {
        self.dp * self.tp * self.pp * self.cp
    }

    /// Total degree of model parallelism (paper's term: tp·pp·cp).
    pub fn model_parallel(&self) -> usize {
        self.tp * self.pp * self.cp
    }

    /// Check the plan against a cluster and model depth.
    pub fn validate(&self, cluster: &Cluster, n_layers: usize)
        -> Result<(), String>
    {
        if self.dp == 0 || self.tp == 0 || self.pp == 0 || self.cp == 0 {
            return Err("all degrees must be >= 1".into());
        }
        if self.ep == 0 {
            return Err("ep must be >= 1".into());
        }
        if self.dp % self.ep != 0 {
            return Err(format!(
                "ep={} must divide dp={} (expert shards tile the \
                 data-parallel group)", self.ep, self.dp));
        }
        if self.world_size() != cluster.world_size() {
            return Err(format!(
                "plan world {} != cluster world {}",
                self.world_size(), cluster.world_size()));
        }
        if n_layers % self.pp != 0 {
            return Err(format!(
                "{} layers not divisible by pp={}", n_layers, self.pp));
        }
        Ok(())
    }

    /// Placement of the tensor-parallel group (innermost, stride 1).
    pub fn tp_placement(&self, cluster: &Cluster) -> GroupPlacement {
        GroupPlacement::strided(cluster, self.tp, 1)
    }

    /// Placement of the context-parallel group (stride tp).
    pub fn cp_placement(&self, cluster: &Cluster) -> GroupPlacement {
        GroupPlacement::strided(cluster, self.cp, self.tp)
    }

    /// Placement of the pipeline group (stride tp·cp): consecutive
    /// stages are tp·cp ranks apart.
    pub fn pp_placement(&self, cluster: &Cluster) -> GroupPlacement {
        GroupPlacement::strided(cluster, self.pp, self.tp * self.cp)
    }

    /// Placement of the data-parallel (FSDP) group, stride tp·cp·pp.
    pub fn dp_placement(&self, cluster: &Cluster) -> GroupPlacement {
        GroupPlacement::strided(cluster, self.dp, self.model_parallel())
    }

    /// Placement of the expert-parallel group: `ep` consecutive ranks
    /// of the DP group (stride tp·cp·pp, the same as DP). Expert
    /// dispatch/combine AllToAll runs over this group.
    pub fn ep_placement(&self, cluster: &Cluster) -> GroupPlacement {
        GroupPlacement::strided(cluster, self.ep, self.model_parallel())
    }

    /// Do adjacent pipeline stages sit on different nodes?
    pub fn pp_crosses_nodes(&self, cluster: &Cluster) -> bool {
        self.pp > 1
            && self.tp * self.cp * self.pp > cluster.gpus_per_node()
    }
}

impl std::fmt::Display for ParallelPlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        // ep = 1 keeps the historical spelling so every pre-MoE CSV,
        // store key, and golden figure stays byte-identical.
        write!(f, "dp{}tp{}pp{}cp{}", self.dp, self.tp, self.pp, self.cp)?;
        if self.ep > 1 {
            write!(f, "ep{}", self.ep)?;
        }
        Ok(())
    }
}

/// Enumerate all plans filling `cluster` with tp/pp degrees from the
/// paper's sweep set {1,2,4,8,16} (§3) and optional cp degrees.
pub fn enumerate_plans(
    cluster: &Cluster,
    n_layers: usize,
    with_cp: bool,
) -> Vec<ParallelPlan> {
    let world = cluster.world_size();
    let degrees = [1usize, 2, 4, 8, 16];
    let cp_degrees: &[usize] =
        if with_cp { &[1, 2, 4, 8] } else { &[1] };
    let mut plans = Vec::new();
    for &tp in &degrees {
        for &pp in &degrees {
            for &cp in cp_degrees {
                let mp = tp * pp * cp;
                if mp > world || world % mp != 0 {
                    continue;
                }
                let plan = ParallelPlan::new(world / mp, tp, pp, cp);
                if plan.validate(cluster, n_layers).is_ok() {
                    plans.push(plan);
                }
            }
        }
    }
    plans
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::Generation;

    fn h100(nodes: usize) -> Cluster {
        Cluster::new(Generation::H100, nodes)
    }

    #[test]
    fn world_size_composes() {
        let p = ParallelPlan::new(16, 4, 2, 2);
        assert_eq!(p.world_size(), 256);
        assert_eq!(p.model_parallel(), 16);
    }

    #[test]
    fn validate_checks_world_and_layers() {
        let c = h100(4); // 32 GPUs
        assert!(ParallelPlan::new(8, 4, 1, 1).validate(&c, 32).is_ok());
        assert!(ParallelPlan::new(8, 2, 1, 1).validate(&c, 32).is_err());
        // 32 layers not divisible by pp=6
        let c2 = h100(6);
        assert!(ParallelPlan::new(8, 1, 6, 1).validate(&c2, 32).is_err());
    }

    #[test]
    fn tp8_stays_intra_node_tp16_crosses() {
        let c = h100(32);
        let p8 = ParallelPlan::new(32, 8, 1, 1);
        assert!(!p8.tp_placement(&c).crosses_nodes);
        let p16 = ParallelPlan::new(16, 16, 1, 1);
        assert!(p16.tp_placement(&c).crosses_nodes);
    }

    #[test]
    fn dp_group_shrinks_with_model_parallelism() {
        // §4.3: FSDP collectives run over world/(tp·pp).
        let c = h100(32); // 256 GPUs
        let baseline = ParallelPlan::data_parallel(256);
        let mp = ParallelPlan::new(32, 4, 2, 1);
        assert_eq!(baseline.dp_placement(&c).size, 256);
        assert_eq!(mp.dp_placement(&c).size, 32);
        // Fewer group members share each node's InfiniBand.
        assert!(mp.dp_placement(&c).ranks_per_node
                < baseline.dp_placement(&c).ranks_per_node);
    }

    #[test]
    fn dp_group_one_rank_per_node_when_mp_fills_node() {
        let c = h100(4);
        let p = ParallelPlan::new(4, 8, 1, 1);
        let place = p.dp_placement(&c);
        assert_eq!(place.ranks_per_node, 1);
        assert_eq!(place.nodes, 4);
    }

    #[test]
    fn enumerate_covers_paper_sweep() {
        let c = h100(32); // 256 GPUs, 7B has 32 layers
        let plans = enumerate_plans(&c, 32, false);
        // Must include the pure-DP baseline and tp2/tp4 (Fig. 6 winners).
        assert!(plans.contains(&ParallelPlan::data_parallel(256)));
        assert!(plans.contains(&ParallelPlan::new(128, 2, 1, 1)));
        assert!(plans.contains(&ParallelPlan::new(64, 4, 1, 1)));
        assert!(plans.contains(&ParallelPlan::new(16, 1, 16, 1)));
        // All valid and unique.
        let mut seen = std::collections::HashSet::new();
        for p in &plans {
            assert!(p.validate(&c, 32).is_ok());
            assert!(seen.insert(*p));
        }
    }

    #[test]
    fn ep_divides_dp_and_keeps_world_size() {
        let c = h100(4); // 32 GPUs
        let p = ParallelPlan::new(8, 4, 1, 1).with_ep(4);
        assert!(p.validate(&c, 32).is_ok());
        assert_eq!(p.world_size(), 32); // ep is not a world factor
        // ep ∤ dp is rejected with a pointed message.
        let bad = ParallelPlan::new(8, 4, 1, 1).with_ep(3);
        let err = bad.validate(&c, 32).unwrap_err();
        assert!(err.contains("ep=3") && err.contains("dp=8"), "{err}");
        assert!(ParallelPlan::new(8, 4, 1, 1).with_ep(0)
            .validate(&c, 32).is_err());
    }

    #[test]
    fn ep_placement_strides_like_dp() {
        let c = h100(4); // 32 GPUs
        let p = ParallelPlan::new(8, 2, 2, 1).with_ep(4);
        let ep = p.ep_placement(&c);
        let dp = p.dp_placement(&c);
        assert_eq!(ep.size, 4);
        assert_eq!(dp.size, 8);
        // Same stride (tp·cp·pp), smaller group.
        assert_eq!(p.model_parallel(), 4);
    }

    #[test]
    fn display_hides_ep1_appends_ep_otherwise() {
        let p = ParallelPlan::new(8, 2, 2, 1);
        assert_eq!(p.to_string(), "dp8tp2pp2cp1");
        assert_eq!(p.with_ep(4).to_string(), "dp8tp2pp2cp1ep4");
    }

    #[test]
    fn pp_cross_node_detection() {
        let c = h100(4);
        // tp=8 fills the node; pp stages land on different nodes.
        assert!(ParallelPlan::new(1, 8, 4, 1).pp_crosses_nodes(&c));
        // tp=2, pp=2: both stages inside one node.
        assert!(!ParallelPlan::new(8, 2, 2, 1).pp_crosses_nodes(&c));
    }
}
