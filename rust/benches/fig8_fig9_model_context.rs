//! Bench: Figs. 8, 9, 11 & 12 — model-size scaling, context-length
//! scaling, pretraining-scale search, and context parallelism.

use dtsim::hardware::Generation;
use dtsim::model::{self, LLAMA_7B};
use dtsim::parallelism::ParallelPlan;
use dtsim::planner::{self, SweepRequest};
use dtsim::sim::{simulate, SimConfig};
use dtsim::topology::Cluster;
use dtsim::util::bench::{bb, bench, bench_quick, group};

fn main() {
    group("fig8/fig9/fig11/fig12: model & context scaling");

    // Fig. 8: per-size simulation (70B is the deepest event graph).
    for name in ["1b", "7b", "70b"] {
        let arch = *model::by_name(name).unwrap();
        let cluster = Cluster::new(Generation::H100, 32);
        let w = cluster.world_size();
        let cfg = SimConfig::fsdp(
            arch, cluster, ParallelPlan::data_parallel(w), 256, 1,
            4096);
        bench(&format!("simulate_{name}/256gpus"), || {
            bb(simulate(bb(&cfg)));
        });
    }

    // Fig. 9: long-context simulation.
    let cluster = Cluster::new(Generation::H100, 32);
    let w = cluster.world_size();
    let long = SimConfig::fsdp(
        LLAMA_7B, cluster, ParallelPlan::data_parallel(w), w, 1,
        32768);
    bench("simulate_seq32k/256gpus", || {
        bb(simulate(bb(&long)));
    });

    // Fig. 12: context-parallel iteration.
    let cp4 = SimConfig::fsdp(
        LLAMA_7B, cluster, ParallelPlan::new(w / 4, 1, 1, 4), 256, 1,
        4096);
    bench("simulate_cp4/256gpus", || {
        bb(simulate(bb(&cp4)));
    });

    // Fig. 11: pretraining-scale planner point (70B @ 2048 GPUs).
    bench_quick("fig11_best_70b_2048gpus", || {
        let req = SweepRequest::fsdp(
            *model::by_name("70b").unwrap(),
            Cluster::new(Generation::H100, 256), 1024, 4096);
        bb(planner::best(&req));
    });
}
