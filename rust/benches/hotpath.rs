//! Bench: L3 hot paths outside the figure harness — the event engine,
//! the real ring all-reduce, data pipeline, JSON/manifest parsing, and
//! (when artifacts exist) the PJRT execute path itself.

use dtsim::coordinator::data::{Corpus, CorpusConfig};
use dtsim::coordinator::{ring_allreduce, ring_allreduce_threaded};
use dtsim::hardware::Generation;
use dtsim::model::LLAMA_70B;
use dtsim::parallelism::ParallelPlan;
use dtsim::runtime::{tokens_literal, ModelBundle, Runtime};
use dtsim::sim::{build_engine, SimConfig};
use dtsim::topology::Cluster;
use dtsim::util::bench::{bb, bench, bench_quick, group};
use dtsim::util::json::Json;
use dtsim::util::rng::Rng;

fn main() {
    group("hotpath: event engine");
    // Deepest graph in the figure set: 70B, pp8, m=16.
    let cluster = Cluster::new(Generation::H100, 32);
    let cfg = SimConfig::fsdp(
        LLAMA_70B, cluster, ParallelPlan::new(4, 8, 8, 1), 64, 1, 4096);
    let eng = build_engine(&cfg);
    println!("event graph: {} events", eng.events.len());
    bench("engine_build/70b_pp8_m16", || {
        bb(build_engine(bb(&cfg)));
    });
    bench("engine_run/70b_pp8_m16", || {
        bb(eng.run());
    });
    let tl = eng.run();
    bench("device_stats/70b_pp8_m16", || {
        bb(tl.device_stats(&eng));
    });

    group("hotpath: ring all-reduce (real, 27M params)");
    let mut rng = Rng::new(1);
    let n = 27_000_000usize / 4; // bench-sized buffers, 4 ranks
    let bufs: Vec<Vec<f32>> = (0..4)
        .map(|_| (0..n).map(|_| rng.next_gaussian() as f32).collect())
        .collect();
    bench_quick("ring_allreduce_seq/4x6.75M", || {
        let mut b = bufs.clone();
        ring_allreduce(&mut b);
        bb(b);
    });
    bench_quick("ring_allreduce_threaded/4x6.75M", || {
        bb(ring_allreduce_threaded(bufs.clone()));
    });

    group("hotpath: data pipeline + manifest");
    let corpus = Corpus::new(CorpusConfig::for_model(4096, 256, 0));
    bench("corpus_batch/8x256", || {
        bb(corpus.batch(bb(0), bb(0), 8));
    });
    if let Ok(text) =
        std::fs::read_to_string("artifacts/tiny/manifest.json")
    {
        bench("manifest_json_parse/tiny", || {
            bb(Json::parse(bb(&text)).unwrap());
        });
    }

    group("hotpath: PJRT execute (requires artifacts)");
    let dir = dtsim::runtime::artifacts_root().join("tiny");
    if dir.join("manifest.json").exists() {
        let rt = Runtime::cpu().unwrap();
        let b = ModelBundle::load(&rt, &dir).unwrap();
        let params = b.init_params(0).unwrap();
        let batch = b.manifest.batch;
        let seq = b.manifest.seq;
        let toks: Vec<i32> =
            (0..batch * seq).map(|i| (i % 200) as i32).collect();
        bench_quick("pjrt_grad_step/tiny", || {
            let mut args: Vec<xla::Literal> = params
                .iter()
                .map(|p| p.to_literal().unwrap())
                .collect();
            args.push(tokens_literal(&toks, &[batch, seq]).unwrap());
            args.push(tokens_literal(&toks, &[batch, seq]).unwrap());
            bb(b.grad_step.run(&args).unwrap());
        });
        bench_quick("literal_roundtrip/tiny_params", || {
            for p in &params {
                let lit = p.to_literal().unwrap();
                bb(dtsim::runtime::HostTensor::from_literal(&lit)
                    .unwrap());
            }
        });
    } else {
        println!("(skipped — run `make artifacts`)");
    }
}
