//! Bench: StudyRunner parallel speedup and cache effectiveness on the
//! Fig. 6 parallelization sweep (the figure harness's dominant cost),
//! plus the fused-fast-path vs event-engine single-evaluation split.
//! The grid is pinned (`study::bench_pinned_study`) so numbers are
//! comparable across PRs; `dtsim bench` runs the same grid in CI.

use dtsim::hardware::Generation;
use dtsim::model::LLAMA_7B;
use dtsim::parallelism::ParallelPlan;
use dtsim::sim::{simulate_engine, simulate_in, SimArena, SimConfig};
use dtsim::study::{bench_pinned_hw_study, bench_pinned_sched_study,
                   bench_pinned_study, StudyRunner};
use dtsim::topology::Cluster;
use dtsim::util::bench::{bb, bench, bench_quick, group};

fn main() {
    group("study runner: fig6 sweep (256 GPUs, gbs 512)");

    let study = bench_pinned_study();
    let points = study.expand();
    println!("grid points after constraints: {}", points.len());

    bench("expand/fig6_grid", || {
        bb(bench_pinned_study().expand());
    });

    bench_quick("run/sequential", || {
        let mut runner = StudyRunner::sequential();
        bb(runner.run(bb(&study)));
    });

    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4);
    for threads in [2usize, 4, cores] {
        bench_quick(&format!("run/threads{threads}"), || {
            let mut runner = StudyRunner::new(threads);
            bb(runner.run(bb(&study)));
        });
    }

    // Fully-warmed cache: the cost of re-rendering a figure once every
    // configuration has been simulated.
    let mut warmed = StudyRunner::auto();
    warmed.run(&study);
    bench("run/cache_hit", || {
        bb(warmed.run(bb(&study)));
    });
    let (hits, misses) = warmed.cost_cache_stats();
    println!("collective cost memo: {hits} hits / {misses} misses");
    let (steady, fallback) = warmed.steady_stats();
    let (intervals, runs) = warmed.interval_stats();
    println!(
        "steady-state compression: {steady} wave / {fallback} queue \
         evaluations; {intervals} intervals -> {runs} runs \
         ({:.1}x)",
        if runs > 0 { intervals as f64 / runs as f64 } else { 0.0 });

    group("simulate: fused fast path vs event-graph engine");
    let cluster = Cluster::new(Generation::H100, 32);
    let world = cluster.world_size();
    let cfgs = [
        ("dp256_m2", SimConfig::fsdp(
            LLAMA_7B, cluster, ParallelPlan::data_parallel(world),
            2 * world, 2, 4096)),
        ("tp2pp2_m8", SimConfig::fsdp(
            LLAMA_7B, cluster, ParallelPlan::new(64, 2, 2, 1),
            512, 1, 4096)),
    ];
    for (name, cfg) in &cfgs {
        let mut arena = SimArena::new();
        bench(&format!("simulate_fused/{name}"), || {
            bb(simulate_in(bb(cfg), &mut arena));
        });
        bench(&format!("simulate_engine/{name}"), || {
            bb(simulate_engine(bb(cfg)));
        });
    }

    group("planner: pruned best vs exhaustive sweep");
    bench_quick("best_of/fig6_grid", || {
        let mut runner = StudyRunner::sequential();
        bb(runner.best_of(bb(&study)));
    });
    // Parallel bound-sharing search: workers publish the incumbent
    // throughput through a shared atomic, tightening everyone's prune.
    for threads in [2usize, cores] {
        bench_quick(&format!("best_of/fig6_grid_threads{threads}"), || {
            let mut runner = StudyRunner::new(threads);
            bb(runner.best_of(bb(&study)));
        });
    }

    group("study runner: schedule variants (interleaved/zero3)");
    let sched = bench_pinned_sched_study();
    println!("sched grid points after constraints: {}",
             sched.expand().len());
    bench_quick("run/sched_sequential", || {
        let mut runner = StudyRunner::sequential();
        bb(runner.run(bb(&sched)));
    });
    bench_quick("best_of/sched_grid", || {
        let mut runner = StudyRunner::sequential();
        bb(runner.best_of(bb(&sched)));
    });

    group("study runner: hardware axis (catalog built-ins)");
    let hw = bench_pinned_hw_study();
    println!("hw grid points after constraints: {}", hw.expand().len());
    bench_quick("run/hw_sequential", || {
        let mut runner = StudyRunner::sequential();
        bb(runner.run(bb(&hw)));
    });
    let mut hw_warm = StudyRunner::sequential();
    hw_warm.run(&hw);
    bench("run/hw_cache_hit", || {
        bb(hw_warm.run(bb(&hw)));
    });
    let (hits, misses) = hw_warm.cost_cache_stats();
    println!("hw collective cost memo: {hits} hits / {misses} misses");
}
