//! Bench: StudyRunner parallel speedup and cache effectiveness on the
//! Fig. 6 parallelization sweep (the figure harness's dominant cost).

use dtsim::hardware::Generation;
use dtsim::model::LLAMA_7B;
use dtsim::study::{PlanAxis, Study, StudyRunner};
use dtsim::util::bench::{bb, bench, bench_quick, group};

fn fig6_study() -> Study {
    Study::builder("bench-fig6")
        .arch(LLAMA_7B)
        .generation(Generation::H100)
        .nodes([32])
        .plans(PlanAxis::Sweep { with_cp: false })
        .global_batches([512])
        .micro_batch_divisors()
        .memory_cap(0.94)
        .build()
}

fn main() {
    group("study runner: fig6 sweep (256 GPUs, gbs 512)");

    let study = fig6_study();
    let points = study.expand();
    println!("grid points after constraints: {}", points.len());

    bench("expand/fig6_grid", || {
        bb(fig6_study().expand());
    });

    bench_quick("run/sequential", || {
        let mut runner = StudyRunner::sequential();
        bb(runner.run(bb(&study)));
    });

    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4);
    for threads in [2usize, 4, cores] {
        bench_quick(&format!("run/threads{threads}"), || {
            let mut runner = StudyRunner::new(threads);
            bb(runner.run(bb(&study)));
        });
    }

    // Fully-warmed cache: the cost of re-rendering a figure once every
    // configuration has been simulated.
    let mut warmed = StudyRunner::auto();
    warmed.run(&study);
    bench("run/cache_hit", || {
        bb(warmed.run(bb(&study)));
    });
}
