//! Bench: Figs. 1 & 3 — full weak-scaling iteration simulation at the
//! paper's scales (this is the figure harness's dominant cost).

use dtsim::hardware::Generation;
use dtsim::metrics;
use dtsim::model::LLAMA_7B;
use dtsim::parallelism::ParallelPlan;
use dtsim::sim::{simulate, SimConfig};
use dtsim::topology::Cluster;
use dtsim::util::bench::{bb, bench, group};

fn weak(nodes: usize) -> SimConfig {
    let cluster = Cluster::new(Generation::H100, nodes);
    let w = cluster.world_size();
    SimConfig::fsdp(LLAMA_7B, cluster, ParallelPlan::data_parallel(w),
                    2 * w, 2, 4096)
}

fn main() {
    group("fig1/fig3: weak-scaling iteration simulation");
    for nodes in [1usize, 16, 256] {
        let cfg = weak(nodes);
        bench(&format!("simulate_weak/{}gpus", nodes * 8), || {
            bb(simulate(bb(&cfg)));
        });
    }
    let cfg = weak(256);
    bench("evaluate_metrics/2048gpus", || {
        bb(metrics::evaluate(bb(&cfg)));
    });

    // Full figure regeneration end to end.
    bench("regen_fig1_all_points", || {
        for nodes in [1usize, 2, 4, 8, 16, 32, 64, 128, 256] {
            bb(metrics::evaluate(&weak(nodes)));
        }
    });
}
