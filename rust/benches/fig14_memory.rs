//! Bench: Fig. 14 — memory-model evaluation (the planner feasibility
//! filter's hot path) plus Table 1 spec access.

use dtsim::hardware::Generation;
use dtsim::memory;
use dtsim::model::{LLAMA_70B, LLAMA_7B};
use dtsim::parallelism::ParallelPlan;
use dtsim::util::bench::{bb, bench, group};

fn main() {
    group("fig14/table1: memory model");

    bench("per_gpu_memory/7b_dp2048", || {
        bb(memory::per_gpu_memory(
            bb(&LLAMA_7B), &ParallelPlan::data_parallel(2048), 2, 4096,
            1));
    });
    bench("per_gpu_memory/70b_tp8pp4", || {
        bb(memory::per_gpu_memory(
            bb(&LLAMA_70B), &ParallelPlan::new(8, 8, 4, 1), 1, 4096,
            4));
    });
    bench("fits_check/70b", || {
        bb(memory::fits(bb(&LLAMA_70B), &ParallelPlan::new(16, 4, 4, 1),
                        1, 4096, 4, 80e9));
    });
    bench("regen_fig14_all_points", || {
        for dp in [8usize, 16, 32, 64, 128, 256, 512, 1024, 2048] {
            bb(memory::per_gpu_memory(
                &LLAMA_7B, &ParallelPlan::data_parallel(dp), 2, 4096,
                1));
        }
    });
    bench("table1_spec_access", || {
        for g in Generation::ALL {
            bb(g.spec());
        }
    });
}
