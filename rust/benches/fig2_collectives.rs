//! Bench: Fig. 2 — collective cost-model evaluation across world sizes
//! and message sizes (also regenerates the figure's data points and
//! prints them, so `cargo bench` doubles as a repro run).

use dtsim::collectives::{busbw_gbps, collective_time, Collective};
use dtsim::hardware::Generation;
use dtsim::topology::{Cluster, GroupPlacement};
use dtsim::util::bench::{bb, bench, group};

fn main() {
    group("fig2: NCCL collective model");

    // Figure data (shape check printed for eyeballing).
    println!("nodes | AllReduce busbw | AllGather busbw (GB/s, 1GB msg)");
    for nodes in [4usize, 32, 128, 512] {
        let c = Cluster::new(Generation::H100, nodes);
        let p = GroupPlacement::strided(&c, c.world_size(), 1);
        println!("{:>5} | {:>15.1} | {:>15.1}",
                 nodes,
                 busbw_gbps(Collective::AllReduce, 1e9, &c, &p),
                 busbw_gbps(Collective::AllGather, 1e9, &c, &p));
    }

    // Cost-model evaluation throughput (planner hot path).
    for nodes in [8usize, 256] {
        let c = Cluster::new(Generation::H100, nodes);
        let p = GroupPlacement::strided(&c, c.world_size(), 1);
        bench(&format!("allgather_cost/{nodes}nodes"), || {
            bb(collective_time(Collective::AllGather, bb(422e6), &c,
                               &p));
        });
        bench(&format!("allreduce_cost/{nodes}nodes"), || {
            bb(collective_time(Collective::AllReduce, bb(67e6), &c,
                               &p));
        });
    }

    // Placement computation (topology hot path).
    let c = Cluster::new(Generation::H100, 256);
    bench("group_placement/2048ranks", || {
        bb(GroupPlacement::strided(&c, 2048, 1));
    });
}
