//! Bench: Figs. 6, 7 & 10 — parallelization-strategy sweeps at 256
//! GPUs (and the A100/H100 generation comparison).

use dtsim::hardware::Generation;
use dtsim::model::LLAMA_7B;
use dtsim::parallelism::{enumerate_plans, ParallelPlan};
use dtsim::planner::{self, SweepRequest};
use dtsim::sim::{simulate, SimConfig};
use dtsim::topology::Cluster;
use dtsim::util::bench::{bb, bench, bench_quick, group};

fn main() {
    group("fig6/fig7/fig10: parallelism sweeps");

    let cluster = Cluster::new(Generation::H100, 32);
    bench("enumerate_plans/256gpus", || {
        bb(enumerate_plans(bb(&cluster), 32, true));
    });

    // Single candidate evaluation — the sweep's unit of work.
    let tp2 = SimConfig::fsdp(
        LLAMA_7B, cluster, ParallelPlan::new(128, 2, 1, 1), 512, 2,
        4096);
    bench("simulate_tp2/256gpus", || {
        bb(simulate(bb(&tp2)));
    });
    let pp4 = SimConfig::fsdp(
        LLAMA_7B, cluster, ParallelPlan::new(64, 1, 4, 1), 512, 2,
        4096);
    bench("simulate_pp4_1f1b/256gpus", || {
        bb(simulate(bb(&pp4)));
    });

    for gen in [Generation::A100, Generation::H100] {
        let req = SweepRequest::fsdp(
            LLAMA_7B, Cluster::new(gen, 32), 512, 4096);
        bench_quick(&format!("full_sweep_{gen}/256gpus_gbs512"), || {
            bb(planner::sweep(bb(&req)));
        });
    }
}
