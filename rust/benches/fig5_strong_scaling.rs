//! Bench: Fig. 5 — strong-scaling planner search (sweep + simulate per
//! candidate plan) at fixed global batch.

use dtsim::hardware::Generation;
use dtsim::model::LLAMA_7B;
use dtsim::planner::{self, SweepRequest};
use dtsim::topology::Cluster;
use dtsim::util::bench::{bb, bench, bench_quick, group};

fn main() {
    group("fig5: strong-scaling planner");
    for nodes in [2usize, 32] {
        let req = SweepRequest::fsdp(
            LLAMA_7B, Cluster::new(Generation::H100, nodes), 32, 4096);
        bench(&format!("planner_sweep/{nodes}nodes_gbs32"), || {
            bb(planner::sweep(bb(&req)));
        });
    }
    bench_quick("regen_fig5_all_points", || {
        for nodes in [2usize, 4, 8, 16, 32] {
            let req = SweepRequest::fsdp(
                LLAMA_7B, Cluster::new(Generation::H100, nodes), 32,
                4096);
            bb(planner::best(&req));
        }
    });
}
